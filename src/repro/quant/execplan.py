"""Quantized plan lowering — fused, batch-vectorized integer kernels.

The int8/int4 replay lowers through the same :class:`ExecPlan`
machinery as the float path (:mod:`repro.core.execplan`) but coalesces
to **one fused kernel per op** instead of one per program step: the
interpreter's integer accumulation is order-exact, and every
dequant→op→requant epilogue is elementwise, so a whole-op kernel
reproduces the interpreter's per-window stored integers bit for bit
while collapsing a tile-split op's dozens of Python steps into one.

Everything per-request the interpreter re-derives is resolved once at
lowering time:

  * weights are pre-gathered and pre-cast — int64 kernels for the
    conv/fc accumulators (depthwise kernels pre-transposed), int64
    biases, and the fused rescale vector ``s_x * s_w[c]``;
  * input zero points, per-tensor qparams, pad geometry and pooling
    windows are baked into each closure;
  * the batch dimension runs through every kernel (integer einsum /
    matmul over ``(B, ...)``), so one replay serves N requests.

Kernel bodies mirror :mod:`repro.quant.ptq`'s integer kernels
(`q_conv`/`q_fc`/`q_maxpool`/...) exactly — same pad values, same
int32/int64 accumulation, same float32 epilogue expressions — so plan
outputs match the interpretive replay's stored integers (the property
tests in ``tests/test_execplan.py`` pin this at batch 1/3/8 and on
ragged tails).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.execplan import PlanConsts, PlanStep
from repro.core.ir import (Graph, _apply_act, _attention_ref,
                           _kvappend_ref, _layernorm_ref, _softmax_ref)
from repro.core.program import NPUProgram
from repro.core.tiling import TilingResult

from .ptq import _NEG_SENTINEL, QuantizedModel
from .qparams import dequantize, quantize


def _gemm_consts(qm: QuantizedModel, op, zp: int,
                 in_qp) -> Dict[str, np.ndarray]:
    """Derived fc/matmul constants: float64 dgemm weight (exact for
    integer operands — see the conv kernel note), zero-point-folded
    bias, fused rescale vector."""
    wT = np.ascontiguousarray(
        qm.qweights[op.inputs[1]][:, 0, 0, :].astype(np.float64).T)
    biasf = qm.qweights[op.inputs[2]].astype(np.float64) \
        if len(op.inputs) > 2 else np.float64(0.0)
    biasf = biasf - zp * wT.sum(axis=0)   # zp folded (exact ints)
    s_x = float(np.atleast_1d(in_qp.scale)[0])
    s_w = np.atleast_1d(qm.qp(op.inputs[1]).scale).astype(np.float32)
    return {"wT": wT, "biasf": np.asarray(biasf), "sc": s_x * s_w}


def lower_quant_steps(qm: QuantizedModel, g: Graph, tiling: TilingResult,
                      program: NPUProgram, weights: Dict[str, np.ndarray],
                      ids: Dict[str, int],
                      consts: Optional[PlanConsts] = None
                      ) -> Tuple[List[PlanStep], str]:
    """One fused integer kernel per op, in topological order.

    The derived kernel constants (transposed/cast integer kernels,
    zero-point-folded biases, fused rescale vectors) go through the
    ``consts`` get-or-compute store — a persisted store (version-3
    artifacts) serves them without touching the raw weight pages."""
    cs = consts if consts is not None else PlanConsts()
    steps: List[PlanStep] = []

    for op in g.topo_ops():
        a = op.attrs
        k = op.kind
        oid = ids[op.outputs[0]]
        out_qp = qm.qp(op.outputs[0])
        label = f"{op.name}@op"

        if k in ("conv", "dwconv"):
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            s = a["stride"]
            pt, pb, pl, pr = a["pad"]
            fh, fw = a["k"]
            dw = k == "dwconv"
            in_qp = qm.qp(x.name)
            zp = int(np.atleast_1d(in_qp.zero_point)[0])

            def _conv_consts(op=op, dw=dw, fh=fh, fw=fw, zp=zp,
                             in_qp=in_qp):
                # Accumulate in float64 through BLAS: every operand is
                # an integer (|x - zp| <= 255, |w| <= 127, dot lengths
                # << 2^35), so every product and partial sum is an
                # exactly-representable integer < 2^53 — the result
                # equals the interpreter's int32/int64 accumulation bit
                # for bit, regardless of summation order, and dgemm
                # vectorizes across the batch.  The zero point is
                # folded into the bias ((x - zp)·W == x·W - zp·ΣW), and
                # padding pads the *stored* int8 values with zp, so no
                # full-size subtract pass runs per request.
                w_q = qm.qweights[op.inputs[1]]
                if dw:
                    kerf = np.ascontiguousarray(
                        np.transpose(w_q[:, :, :, 0], (1, 2, 0))
                        .astype(np.float64).reshape(fh * fw, -1))
                    wsum = kerf.sum(axis=0)             # (C,)
                    dot_len = fh * fw
                else:
                    kerf = np.ascontiguousarray(
                        w_q.astype(np.float64).reshape(w_q.shape[0],
                                                       -1).T)
                    wsum = kerf.sum(axis=0)             # (outC,)
                    dot_len = kerf.shape[0]
                biasf = qm.qweights[op.inputs[2]].astype(np.float64) \
                    if len(op.inputs) > 2 else np.float64(0.0)
                biasf = biasf - zp * wsum
                # float32 is exact for integer accumulation while every
                # partial sum stays below 2^24; short dots (depthwise
                # taps, small-channel pointwise) qualify and run at
                # half the memory bandwidth of float64.
                max_bias = float(np.max(np.abs(np.atleast_1d(biasf))))
                if dot_len * 255 * 127 + max_bias < 2.0 ** 24:
                    fdt = np.float32
                else:
                    fdt = np.float64
                s_x = float(np.atleast_1d(in_qp.scale)[0])
                s_w = np.atleast_1d(qm.qp(op.inputs[1]).scale) \
                    .astype(np.float32)
                return {"kerf": kerf.astype(fdt),
                        "biasf": np.asarray(biasf, dtype=fdt),
                        "sc": s_x * s_w}
            got = cs.group(label, ("kerf", "biasf", "sc"), _conv_consts)
            kerf, biasf, sc = got["kerf"], got["biasf"], got["sc"]
            fdt = kerf.dtype
            act = a.get("act", "none")
            oh, ow = g.tensors[op.outputs[0]].shape[:2]

            pointwise = fh == 1 and fw == 1 and not dw \
                and (pt, pb, pl, pr) == (0, 0, 0, 0)

            def run(bufs, n, xid=xid, oid=oid, zp=zp, pt=pt, pb=pb,
                    pl=pl, pr=pr, fh=fh, fw=fw, s=s, kerf=kerf,
                    biasf=biasf, sc=sc, act=act, out_qp=out_qp,
                    dw=dw, oh=oh, ow=ow, pointwise=pointwise, fdt=fdt):
                xq = bufs[xid][:n]
                if pointwise:
                    # 1x1 stride-s conv == strided gemm, no im2col
                    xs_ = xq[:, ::s, ::s, :] if s != 1 else xq
                    acc = xs_.reshape(-1, xs_.shape[-1]).astype(fdt) @ kerf
                    acc = acc.reshape(n, oh, ow, -1)
                else:
                    xp = np.pad(xq, ((0, 0), (pt, pb), (pl, pr), (0, 0)),
                                constant_values=zp)
                    C = xp.shape[-1]
                    if dw:
                        # tap-by-tap accumulation straight off the padded
                        # input (no im2col materialization)
                        xpf = xp.astype(fdt)
                        acc = np.zeros((n, oh, ow, C), dtype=fdt)
                        for i in range(fh):
                            for j in range(fw):
                                acc += xpf[:, i:i + oh * s:s,
                                           j:j + ow * s:s, :] \
                                    * kerf[i * fw + j]
                    else:
                        cols = np.empty((n, oh, ow, fh * fw, C),
                                        dtype=fdt)
                        for i in range(fh):
                            for j in range(fw):
                                cols[:, :, :, i * fw + j, :] = \
                                    xp[:, i:i + oh * s:s,
                                       j:j + ow * s:s, :]
                        acc = cols.reshape(n * oh * ow, fh * fw * C) @ kerf
                        acc = acc.reshape(n, oh, ow, -1)
                acc += biasf
                y = acc.astype(np.float32) * sc
                bufs[oid][:n] = quantize(_apply_act(y, act), out_qp)
            reads = (xid,)
        elif k == "fc":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            zp = int(np.atleast_1d(in_qp.zero_point)[0])
            got = cs.group(label, ("wT", "biasf", "sc"),
                           lambda: _gemm_consts(qm, op, zp, in_qp))
            wT, biasf, sc = got["wT"], got["biasf"], got["sc"]
            act = a.get("act", "none")

            def run(bufs, n, xid=xid, oid=oid, wT=wT,
                    biasf=biasf, sc=sc, act=act, out_qp=out_qp):
                xi = bufs[xid][:n].reshape(n, -1).astype(np.float64)
                acc = xi @ wT
                acc += biasf
                y = acc.astype(np.float32) * sc
                q = quantize(_apply_act(y, act), out_qp)
                bufs[oid][:n] = q.reshape(n, 1, 1, -1)
            reads = (xid,)
        elif k in ("add", "mul"):
            xs = g.act_inputs(op)
            i0, i1 = ids[xs[0].name], ids[xs[1].name]
            qp0, qp1 = qm.qp(xs[0].name), qm.qp(xs[1].name)
            act = a.get("act", "none")
            is_add = k == "add"

            def run(bufs, n, i0=i0, i1=i1, qp0=qp0, qp1=qp1, act=act,
                    is_add=is_add, oid=oid, out_qp=out_qp):
                a0 = dequantize(bufs[i0][:n], qp0)
                a1 = dequantize(bufs[i1][:n], qp1)
                y = _apply_act(a0 + a1, act) if is_add else a0 * a1
                bufs[oid][:n] = quantize(y, out_qp)
            reads = (i0, i1)
        elif k == "scalar":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            v = a["value"]
            sop = a["op"]

            def run(bufs, n, xid=xid, in_qp=in_qp, v=v, sop=sop,
                    oid=oid, out_qp=out_qp):
                xv = dequantize(bufs[xid][:n], in_qp)
                y = {"add": xv + v, "mul": xv * v, "div": xv / v}[sop]
                bufs[oid][:n] = quantize(y, out_qp)
            reads = (xid,)
        elif k == "act":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            act = a["act"]

            def run(bufs, n, xid=xid, in_qp=in_qp, act=act, oid=oid,
                    out_qp=out_qp):
                y = _apply_act(dequantize(bufs[xid][:n], in_qp), act)
                bufs[oid][:n] = quantize(y, out_qp)
            reads = (xid,)
        elif k == "maxpool":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            kk, s = a["k"], a["stride"]
            pt, pb, pl, pr = a["pad"]

            def run(bufs, n, xid=xid, in_qp=in_qp, kk=kk, s=s, pt=pt,
                    pb=pb, pl=pl, pr=pr, oid=oid, out_qp=out_qp):
                xp = np.pad(bufs[xid][:n].astype(np.int32),
                            ((0, 0), (pt, pb), (pl, pr), (0, 0)),
                            constant_values=_NEG_SENTINEL)
                H, W = xp.shape[1:3]
                oh = (H - kk) // s + 1
                ow = (W - kk) // s + 1
                y = np.full((n, oh, ow, xp.shape[-1]), _NEG_SENTINEL,
                            dtype=np.int32)
                for i in range(kk):
                    for j in range(kk):
                        y = np.maximum(
                            y, xp[:, i:i + oh * s:s, j:j + ow * s:s, :])
                bufs[oid][:n] = quantize(dequantize(y, in_qp), out_qp)
            reads = (xid,)
        elif k == "avgpool":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            zp = int(np.atleast_1d(in_qp.zero_point)[0])
            s_x = float(np.atleast_1d(in_qp.scale)[0])
            if a["k"] == 0:
                def run(bufs, n, xid=xid, zp=zp, s_x=s_x, oid=oid,
                        out_qp=out_qp):
                    xq = bufs[xid][:n]
                    acc = (xq.astype(np.int64) - zp).sum(
                        axis=(1, 2), keepdims=True)
                    m = xq.shape[1] * xq.shape[2]
                    bufs[oid][:n] = quantize(
                        acc.astype(np.float32) * (s_x / m), out_qp)
            else:
                kk, s = a["k"], a["stride"]
                pt, pb, pl, pr = a["pad"]

                def run(bufs, n, xid=xid, zp=zp, s_x=s_x, kk=kk, s=s,
                        pt=pt, pb=pb, pl=pl, pr=pr, oid=oid,
                        out_qp=out_qp):
                    xi = bufs[xid][:n].astype(np.int64) - zp
                    xp = np.pad(xi, ((0, 0), (pt, pb), (pl, pr), (0, 0)))
                    H, W = xp.shape[1:3]
                    oh = (H - kk) // s + 1
                    ow = (W - kk) // s + 1
                    acc = np.zeros((n, oh, ow, xp.shape[-1]),
                                   dtype=np.int64)
                    for i in range(kk):
                        for j in range(kk):
                            acc += xp[:, i:i + oh * s:s, j:j + ow * s:s, :]
                    bufs[oid][:n] = quantize(
                        acc.astype(np.float32) * (s_x / (kk * kk)), out_qp)
            reads = (xid,)
        elif k == "resize":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            f = a["factor"]

            def run(bufs, n, xid=xid, in_qp=in_qp, f=f, oid=oid,
                    out_qp=out_qp):
                rep = np.repeat(np.repeat(bufs[xid][:n], f, axis=1),
                                f, axis=2)
                bufs[oid][:n] = quantize(dequantize(rep, in_qp), out_qp)
            reads = (xid,)
        elif k == "concat":
            xs = g.act_inputs(op)
            xids = tuple(ids[x.name] for x in xs)
            qps = tuple(qm.qp(x.name) for x in xs)

            def run(bufs, n, xids=xids, qps=qps, oid=oid, out_qp=out_qp):
                y = np.concatenate(
                    [dequantize(bufs[i][:n], qp)
                     for i, qp in zip(xids, qps)], axis=-1)
                bufs[oid][:n] = quantize(y, out_qp)
            reads = xids
        elif k == "split":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            oids = tuple(ids[o] for o in op.outputs)
            oqps = tuple(qm.qp(o) for o in op.outputs)
            sections = a["sections"]

            def run(bufs, n, xid=xid, in_qp=in_qp, oids=oids, oqps=oqps,
                    sections=sections):
                parts = np.split(dequantize(bufs[xid][:n], in_qp),
                                 sections, axis=-1)
                for o, qp, p in zip(oids, oqps, parts):
                    bufs[o][:n] = quantize(p, qp)
            steps.append(PlanStep(label, (xid,), oids, run))
            continue
        elif k == "matmul":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            zp = int(np.atleast_1d(in_qp.zero_point)[0])
            got = cs.group(label, ("wT", "biasf", "sc"),
                           lambda: _gemm_consts(qm, op, zp, in_qp))
            wT, biasf, sc = got["wT"], got["biasf"], got["sc"]
            act = a.get("act", "none")
            s_len, wd = g.tensors[op.outputs[0]].shape[:2]

            def run(bufs, n, xid=xid, oid=oid, wT=wT, biasf=biasf,
                    sc=sc, act=act, out_qp=out_qp, s_len=s_len, wd=wd):
                xi = bufs[xid][:n].astype(np.float64)
                acc = xi.reshape(-1, xi.shape[-1]) @ wT
                acc += biasf
                y = acc.astype(np.float32) * sc
                bufs[oid][:n] = quantize(_apply_act(y, act), out_qp) \
                    .reshape(n, s_len, wd, -1)
            reads = (xid,)
        elif k == "layernorm":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)
            gam = qm.qweights[op.inputs[1]]     # kept float32
            bet = qm.qweights[op.inputs[2]]
            eps = a["eps"]

            def run(bufs, n, xid=xid, in_qp=in_qp, gam=gam, bet=bet,
                    eps=eps, oid=oid, out_qp=out_qp):
                xv = dequantize(bufs[xid][:n], in_qp)
                bufs[oid][:n] = quantize(
                    _layernorm_ref(xv, gam, bet, eps), out_qp)
            reads = (xid,)
        elif k == "softmax":
            x = g.act_inputs(op)[0]
            xid = ids[x.name]
            in_qp = qm.qp(x.name)

            def run(bufs, n, xid=xid, in_qp=in_qp, oid=oid,
                    out_qp=out_qp):
                bufs[oid][:n] = quantize(
                    _softmax_ref(dequantize(bufs[xid][:n], in_qp)),
                    out_qp)
            reads = (xid,)
        elif k == "attention":
            qx, kc, vc, ps = g.act_inputs(op)
            qid, kid, vid = ids[qx.name], ids[kc.name], ids[vc.name]
            pid = ids[ps.name]
            qpq, qpk, qpv = (qm.qp(qx.name), qm.qp(kc.name),
                             qm.qp(vc.name))
            attrs = dict(a)

            def run(bufs, n, qid=qid, kid=kid, vid=vid, pid=pid,
                    qpq=qpq, qpk=qpk, qpv=qpv, attrs=attrs, oid=oid,
                    out_qp=out_qp):
                # pos can differ per batch lane; the fused kernel runs
                # per lane like the float path's gemm-bearing kinds
                for b in range(n):
                    y = _attention_ref(dequantize(bufs[qid][b], qpq),
                                       dequantize(bufs[kid][b], qpk),
                                       dequantize(bufs[vid][b], qpv),
                                       bufs[pid][b], attrs)
                    bufs[oid][b] = quantize(y, out_qp)
            reads = (qid, kid, vid, pid)
        elif k == "kvappend":
            cx, nx, ps = g.act_inputs(op)
            cid, nid, pid = ids[cx.name], ids[nx.name], ids[ps.name]
            qpc, qpn = qm.qp(cx.name), qm.qp(nx.name)

            def run(bufs, n, cid=cid, nid=nid, pid=pid, qpc=qpc,
                    qpn=qpn, oid=oid, out_qp=out_qp):
                for b in range(n):
                    y = _kvappend_ref(dequantize(bufs[cid][b], qpc),
                                      dequantize(bufs[nid][b], qpn),
                                      bufs[pid][b])
                    bufs[oid][b] = quantize(y, out_qp)
            reads = (cid, nid, pid)
        else:  # pragma: no cover
            raise NotImplementedError(k)

        steps.append(PlanStep(label, reads, (oid,), run))

    return steps, "op"
