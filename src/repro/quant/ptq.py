"""Post-training quantization pass + quantized functional reference.

The PTQ flow (the paper's INT8 deployment path, §II/§V):

  1. :func:`calibrate` runs the float32 reference executor over a small
     sample set and feeds every activation through a range observer
     (min-max or percentile, per-tensor);
  2. :func:`quantize_graph` annotates the IR in place — activations
     become int8 with per-tensor affine qparams, conv/fc/dwconv weights
     become int8 (or nibble-packed int4) with per-channel symmetric
     qparams, biases become int32 at scale ``s_x * s_w[c]`` — and
     returns a :class:`QuantizedModel` bundling the integer weights;
  3. :func:`quantized_reference_execute` is the *quantized* functional
     oracle: integer conv/fc/dwconv accumulation in int32 with a fused
     float rescale+activation epilogue (the NPU's rescale unit), and
     dequant->float->requant for the vector ops.  The compiled-program
     replay (:mod:`repro.quant.executor`) must match it to within one
     output quantization step.

Because dtype + qparams enter :meth:`Graph.fingerprint`, quantizing a
graph changes its fingerprint — the compiled-program cache can never
serve a stale float32 program for a quantized request (and vice versa).

:func:`cast_graph` is the cost-model-only variant: it sets dtypes
without qparams so latency/tiling experiments can price a precision
without running calibration (not executable on the quantized path).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ir import (Graph, QParams, _apply_act, _attention_ref,
                           _kvappend_ref, _layernorm_ref, _softmax_ref,
                           cached_einsum, reference_execute)

from .observers import PerChannelMinMaxObserver, make_observer
from .qparams import (dequantize, pack_int4, qparams_from_range,
                      qparams_per_channel, quantize, unpack_int4)

#: int-domain sentinel standing in for -inf under maxpool padding.
_NEG_SENTINEL = np.int32(-(1 << 30))


# --------------------------------------------------------------------------
# Calibration
# --------------------------------------------------------------------------


CalibrationTable = Dict[str, object]  # tensor name -> observer


def synthetic_calibration(g: Graph, samples: int = 4, seed: int = 0
                          ) -> List[Dict[str, np.ndarray]]:
    """Deterministic synthetic calibration set: normal inputs for every
    graph input.  The repro's graphs carry deterministic pseudo-random
    weights, so synthetic activations exercise the same dynamic range a
    real input pipeline would here (and PTQ stays reproducible without
    external data)."""
    rng = np.random.default_rng(seed)
    return [{t.name: rng.normal(size=t.shape).astype(np.float32)
             for t in g.inputs}
            for _ in range(max(1, samples))]


def calibrate(g: Graph, weights: Dict[str, np.ndarray],
              sample_inputs: List[Dict[str, np.ndarray]],
              method: str = "minmax",
              percentile: float = 99.9) -> CalibrationTable:
    """Observe every activation range over the calibration samples."""
    if not sample_inputs:
        raise ValueError("calibration needs at least one sample input")
    obs: CalibrationTable = {
        t.name: make_observer(method, percentile)
        for t in g.tensors.values() if not t.is_param}
    for inp in sample_inputs:
        vals = reference_execute(g, inp, weights)
        for name, ob in obs.items():
            ob.update(vals[name])
    return obs


# --------------------------------------------------------------------------
# The PTQ pass
# --------------------------------------------------------------------------


@dataclass
class QuantizedModel:
    """A quantized graph plus everything needed to execute it.

    ``qweights`` holds the stored integer parameter values (int8 arrays;
    int32 for biases; int4 weights are kept *unpacked* one-per-int8 for
    compute, with the packed byte streams in ``packed``).  ``weights_f``
    keeps the float originals for the float-oracle comparison."""

    graph: Graph
    qweights: Dict[str, np.ndarray]
    packed: Dict[str, np.ndarray] = field(default_factory=dict)
    weights_f: Dict[str, np.ndarray] = field(default_factory=dict)
    weight_dtype: str = "int8"
    #: per-output max |quantized - float| observed on the calibration
    #: set (measure_quant_error); the basis of the calibrated tolerance.
    calib_error: Dict[str, float] = field(default_factory=dict)

    def qp(self, name: str) -> QParams:
        qp = self.graph.tensors[name].qparams
        if qp is None:
            raise ValueError(f"tensor {name} has no qparams")
        return qp


def _pos_tensors(g: Graph) -> set:
    """Names of tensors used *only* as sequence-position operands
    (attention input 3 / kvappend input 2).  Positions are integer
    indices, not signal: quantizing one to the calibration range would
    clamp decode at runtime positions the calibration never saw, so
    they stay float32 end to end."""
    pos = set()
    for op in g.ops:
        if op.kind == "attention":
            pos.add(op.inputs[3])
        elif op.kind == "kvappend":
            pos.add(op.inputs[2])
    for op in g.ops:
        for i, nm in enumerate(op.inputs):
            if nm not in pos:
                continue
            if not ((op.kind == "attention" and i == 3)
                    or (op.kind == "kvappend" and i == 2)):
                pos.discard(nm)   # also consumed as a value: quantize it
    return pos


def quantize_graph(g: Graph, weights: Dict[str, np.ndarray],
                   calib: CalibrationTable,
                   weight_dtype: str = "int8") -> QuantizedModel:
    """Annotate ``g`` in place with int8 activation qparams and
    int8/int4 weight qparams; returns the integer-weight bundle."""
    if weight_dtype not in ("int8", "int4"):
        raise ValueError(f"weight_dtype must be int8/int4, {weight_dtype!r}")
    wbits = 8 if weight_dtype == "int8" else 4

    pos_names = _pos_tensors(g)
    for t in g.tensors.values():
        if t.is_param or t.name in pos_names:
            continue
        lo, hi = calib[t.name].range()
        t.qparams = qparams_from_range(float(lo), float(hi), bits=8,
                                       symmetric=False)
        t.dtype = "int8"

    # Tie each KV cache's in/out qparams to the union of their observed
    # ranges: pass-through rows then requantize exactly, and feeding a
    # decode step's cache output back as the next step's input is a
    # fixed point (no drift on rows the step didn't write).
    for op in g.ops:
        if op.kind != "kvappend":
            continue
        lo0, hi0 = calib[op.inputs[0]].range()
        lo1, hi1 = calib[op.outputs[0]].range()
        qp = qparams_from_range(float(min(lo0, lo1)), float(max(hi0, hi1)),
                                bits=8, symmetric=False)
        g.tensors[op.inputs[0]].qparams = qp
        g.tensors[op.outputs[0]].qparams = qp

    qweights: Dict[str, np.ndarray] = {}
    packed: Dict[str, np.ndarray] = {}
    for op in g.ops:
        params = g.param_inputs(op)
        if not params:
            continue
        if op.kind == "layernorm":
            # gamma/beta stay float32: layernorm re-normalizes every
            # row, so integer params buy no bandwidth worth the error;
            # the op executes dequant -> float LN -> requant.
            for pt_ in params:
                qweights[pt_.name] = np.asarray(weights[pt_.name],
                                                np.float32)
            continue
        if op.kind not in ("conv", "dwconv", "fc",
                           "matmul"):  # pragma: no cover
            raise NotImplementedError(
                f"op kind {op.kind} with parameters")
        wt = params[0]
        if len(wt.consumers) != 1:  # bias scale is tied to one consumer
            raise ValueError(f"weight {wt.name} has multiple consumers")
        wobs = PerChannelMinMaxObserver(axis=0)
        wobs.update(weights[wt.name])
        lo, hi = wobs.range()
        wqp = qparams_per_channel(lo, hi, bits=wbits, symmetric=True,
                                  axis=0)
        wt.qparams = wqp
        wt.dtype = weight_dtype
        qw = quantize(weights[wt.name], wqp)
        qweights[wt.name] = qw
        if weight_dtype == "int4":
            packed[wt.name] = pack_int4(qw)
            # the packed stream is the storage of record: compute reads
            # it back through unpack (keeps the format honest end-to-end)
            qweights[wt.name] = unpack_int4(packed[wt.name], qw.size,
                                            qw.shape)
        if len(params) > 1:
            bt = params[1]
            s_x = float(np.atleast_1d(g.tensors[op.inputs[0]].qparams
                                      .scale)[0])
            s_b = (s_x * np.atleast_1d(wqp.scale)).astype(np.float32)
            bqp = QParams(s_b, np.zeros(s_b.shape, dtype=np.int64),
                          bits=32, axis=0)
            bt.qparams = bqp
            bt.dtype = "int32"
            qweights[bt.name] = np.clip(
                np.round(np.asarray(weights[bt.name], np.float64) / s_b),
                bqp.qmin, bqp.qmax).astype(np.int32)
    return QuantizedModel(g, qweights, packed, dict(weights), weight_dtype)


def measure_quant_error(qm: QuantizedModel,
                        sample_inputs: List[Dict[str, np.ndarray]]
                        ) -> Dict[str, float]:
    """Per-output worst |dequantized quantized-oracle - float oracle|
    over the calibration samples.  Stored on the model; the replay's
    *calibrated tolerance* (QuantSemantics.float_tolerance) is a small
    multiple of this — quantization noise accumulates with depth, so an
    output-scale-only bound would be wrong for deep networks."""
    errs: Dict[str, float] = {t.name: 0.0 for t in qm.graph.outputs}
    for inp in sample_inputs:
        ref = reference_execute(qm.graph, inp, qm.weights_f)
        qref = quantized_reference_execute(qm, inp)
        for t in qm.graph.outputs:
            got = dequantize(qref[t.name], qm.qp(t.name))
            errs[t.name] = max(errs[t.name],
                               float(np.max(np.abs(got - ref[t.name]))))
    qm.calib_error = errs
    return errs


def cast_graph(g: Graph, act_dtype: str = "int8",
               weight_dtype: str = "int8",
               bias_dtype: str = "int32") -> Graph:
    """Cost-model-only precision annotation: set dtypes (no qparams) so
    compile_graph prices tiles/DMA/MACs at the target precision without
    calibration.  Not executable on the quantized replay path."""
    for t in g.tensors.values():
        if t.is_param:
            t.dtype = bias_dtype if len(t.shape) == 1 else weight_dtype
        else:
            t.dtype = act_dtype
    return g


# --------------------------------------------------------------------------
# Integer kernels (shared by the quantized reference and program replay)
# --------------------------------------------------------------------------


def _conv2d_int(xi: np.ndarray, w: np.ndarray, stride: int,
                pad: Tuple[int, int, int, int], depthwise: bool
                ) -> np.ndarray:
    """Integer conv: xi (H,W,C) zero-point-subtracted int32, w int
    (outC,fh,fw,inC) -> int64 accumulators (int32-representable: worst
    case sum of |q8*q8| over the benchmark dot lengths < 2^31)."""
    pt, pb, pl, pr = pad
    xp = np.pad(xi, ((pt, pb), (pl, pr), (0, 0)))
    H, W, C = xp.shape
    oc, fh, fw, ic = w.shape
    oh = (H - fh) // stride + 1
    ow = (W - fw) // stride + 1
    cols = np.empty((oh, ow, fh, fw, C), dtype=np.int64)
    for i in range(fh):
        for j in range(fw):
            cols[:, :, i, j, :] = xp[i:i + oh * stride:stride,
                                     j:j + ow * stride:stride, :]
    if depthwise:
        ker = np.transpose(w[:, :, :, 0], (1, 2, 0)).astype(np.int64)
        return cached_einsum("hwijc,ijc->hwc", cols, ker)
    return cached_einsum("hwijc,oijc->hwo",
                         cols.reshape(oh, ow, fh, fw, ic),
                         w.astype(np.int64))


def q_conv(xq: np.ndarray, in_qp: QParams, w_q: np.ndarray,
           w_qp: QParams, bias_q: Optional[np.ndarray], stride: int,
           pad: Tuple[int, int, int, int], depthwise: bool, act: str,
           out_qp: QParams) -> np.ndarray:
    """int8 conv/dwconv: int32 accumulate + fused rescale/act epilogue."""
    zp = int(np.atleast_1d(in_qp.zero_point)[0])
    xi = xq.astype(np.int32) - zp
    acc = _conv2d_int(xi, w_q, stride, pad, depthwise)
    if bias_q is not None:
        acc = acc + bias_q.astype(np.int64)
    s_x = float(np.atleast_1d(in_qp.scale)[0])
    s_w = np.atleast_1d(w_qp.scale).astype(np.float32)
    y = acc.astype(np.float32) * (s_x * s_w)
    return quantize(_apply_act(y, act), out_qp)


def q_fc(xq_flat: np.ndarray, in_qp: QParams, w_q: np.ndarray,
         w_qp: QParams, bias_q: Optional[np.ndarray], act: str,
         out_qp: QParams) -> np.ndarray:
    """int8 fully connected on a flattened (C,) input -> (outC,) int8."""
    zp = int(np.atleast_1d(in_qp.zero_point)[0])
    xi = xq_flat.reshape(-1).astype(np.int64) - zp
    acc = w_q.astype(np.int64) @ xi
    if bias_q is not None:
        acc = acc + bias_q.astype(np.int64)
    s_x = float(np.atleast_1d(in_qp.scale)[0])
    s_w = np.atleast_1d(w_qp.scale).astype(np.float32)
    y = acc.astype(np.float32) * (s_x * s_w)
    return quantize(_apply_act(y, act), out_qp)


def q_matmul(xq: np.ndarray, in_qp: QParams, w_q: np.ndarray,
             w_qp: QParams, bias_q: Optional[np.ndarray], act: str,
             out_qp: QParams) -> np.ndarray:
    """int8 row-wise linear on (S,W,C) token activations -> (S,W,outC)
    int8.  Same integer contract as :func:`q_fc`, kept per-row so the
    sequence axis survives (LM activations put tokens on rows)."""
    zp = int(np.atleast_1d(in_qp.zero_point)[0])
    xi = xq.astype(np.int64) - zp
    acc = cached_einsum("swc,oc->swo", xi, w_q.astype(np.int64))
    if bias_q is not None:
        acc = acc + bias_q.astype(np.int64)
    s_x = float(np.atleast_1d(in_qp.scale)[0])
    s_w = np.atleast_1d(w_qp.scale).astype(np.float32)
    y = acc.astype(np.float32) * (s_x * s_w)
    return quantize(_apply_act(y, act), out_qp)


def q_maxpool(xq: np.ndarray, k: int, s: int,
              pad: Tuple[int, int, int, int], in_qp: QParams,
              out_qp: QParams) -> np.ndarray:
    """Max pool in the int domain (max commutes with the affine map);
    a single dequant->requant maps onto the output grid."""
    pt, pb, pl, pr = pad
    xp = np.pad(xq.astype(np.int32), ((pt, pb), (pl, pr), (0, 0)),
                constant_values=_NEG_SENTINEL)
    H, W, C = xp.shape
    oh = (H - k) // s + 1
    ow = (W - k) // s + 1
    y = np.full((oh, ow, C), _NEG_SENTINEL, dtype=np.int32)
    for i in range(k):
        for j in range(k):
            y = np.maximum(y, xp[i:i + oh * s:s, j:j + ow * s:s, :])
    return quantize(dequantize(y, in_qp), out_qp)


def q_avgpool(xq: np.ndarray, k: int, s: int,
              pad: Tuple[int, int, int, int], in_qp: QParams,
              out_qp: QParams) -> np.ndarray:
    """Average pool: int window sums (exact), one rescale at the end."""
    pt, pb, pl, pr = pad
    zp = int(np.atleast_1d(in_qp.zero_point)[0])
    xi = xq.astype(np.int64) - zp
    xp = np.pad(xi, ((pt, pb), (pl, pr), (0, 0)))
    H, W, C = xp.shape
    oh = (H - k) // s + 1
    ow = (W - k) // s + 1
    acc = np.zeros((oh, ow, C), dtype=np.int64)
    for i in range(k):
        for j in range(k):
            acc += xp[i:i + oh * s:s, j:j + ow * s:s, :]
    s_x = float(np.atleast_1d(in_qp.scale)[0])
    return quantize(acc.astype(np.float32) * (s_x / (k * k)), out_qp)


def q_global_avgpool(xq: np.ndarray, in_qp: QParams,
                     out_qp: QParams) -> np.ndarray:
    zp = int(np.atleast_1d(in_qp.zero_point)[0])
    acc = (xq.astype(np.int64) - zp).sum(axis=(0, 1), keepdims=True)
    n = xq.shape[0] * xq.shape[1]
    s_x = float(np.atleast_1d(in_qp.scale)[0])
    return quantize(acc.astype(np.float32) * (s_x / n), out_qp)


# --------------------------------------------------------------------------
# Quantized functional reference (the oracle the replay must match)
# --------------------------------------------------------------------------


def quantized_reference_execute(qm: QuantizedModel,
                                inputs: Dict[str, np.ndarray]
                                ) -> Dict[str, np.ndarray]:
    """Execute the quantized graph tensor-by-tensor; returns the stored
    integer value of every non-parameter tensor."""
    g = qm.graph
    vals: Dict[str, np.ndarray] = {}
    for t in g.tensors.values():
        if t.kind == "input":
            arr = np.asarray(inputs[t.name], np.float32)
            vals[t.name] = (quantize(arr, qm.qp(t.name))
                            if t.qparams is not None else arr)
        elif t.is_param:
            vals[t.name] = qm.qweights[t.name]

    def deq(name: str) -> np.ndarray:
        if g.tensors[name].qparams is None:   # float32 pos operands
            return vals[name]
        return dequantize(vals[name], qm.qp(name))

    for op in g.topo_ops():
        k = op.kind
        a = op.attrs
        out = op.output
        out_qp = qm.qp(out)
        if k in ("conv", "dwconv"):
            bias = vals[op.inputs[2]] if len(op.inputs) > 2 else None
            vals[out] = q_conv(vals[op.inputs[0]], qm.qp(op.inputs[0]),
                               vals[op.inputs[1]], qm.qp(op.inputs[1]),
                               bias, a["stride"], a["pad"], k == "dwconv",
                               a.get("act", "none"), out_qp)
        elif k == "fc":
            bias = vals[op.inputs[2]] if len(op.inputs) > 2 else None
            w = vals[op.inputs[1]][:, 0, 0, :]
            vals[out] = q_fc(vals[op.inputs[0]], qm.qp(op.inputs[0]),
                             w, qm.qp(op.inputs[1]), bias,
                             a.get("act", "none"), out_qp
                             ).reshape(1, 1, -1)
        elif k == "add":
            y = _apply_act(deq(op.inputs[0]) + deq(op.inputs[1]),
                           a.get("act", "none"))
            vals[out] = quantize(y, out_qp)
        elif k == "mul":
            vals[out] = quantize(deq(op.inputs[0]) * deq(op.inputs[1]),
                                 out_qp)
        elif k == "scalar":
            x = deq(op.inputs[0])
            v = a["value"]
            vals[out] = quantize({"add": x + v, "mul": x * v,
                                  "div": x / v}[a["op"]], out_qp)
        elif k == "act":
            vals[out] = quantize(_apply_act(deq(op.inputs[0]), a["act"]),
                                 out_qp)
        elif k == "maxpool":
            vals[out] = q_maxpool(vals[op.inputs[0]], a["k"], a["stride"],
                                  a["pad"], qm.qp(op.inputs[0]), out_qp)
        elif k == "avgpool":
            if a["k"] == 0:
                vals[out] = q_global_avgpool(vals[op.inputs[0]],
                                             qm.qp(op.inputs[0]), out_qp)
            else:
                vals[out] = q_avgpool(vals[op.inputs[0]], a["k"],
                                      a["stride"], a["pad"],
                                      qm.qp(op.inputs[0]), out_qp)
        elif k == "resize":
            f = a["factor"]
            rep = np.repeat(np.repeat(vals[op.inputs[0]], f, axis=0),
                            f, axis=1)
            vals[out] = quantize(dequantize(rep, qm.qp(op.inputs[0])),
                                 out_qp)
        elif k == "matmul":
            bias = vals[op.inputs[2]] if len(op.inputs) > 2 else None
            w = vals[op.inputs[1]][:, 0, 0, :]
            vals[out] = q_matmul(vals[op.inputs[0]], qm.qp(op.inputs[0]),
                                 w, qm.qp(op.inputs[1]), bias,
                                 a.get("act", "none"), out_qp)
        elif k == "layernorm":
            y = _layernorm_ref(deq(op.inputs[0]), vals[op.inputs[1]],
                               vals[op.inputs[2]], a["eps"])
            vals[out] = quantize(y, out_qp)
        elif k == "softmax":
            vals[out] = quantize(_softmax_ref(deq(op.inputs[0])), out_qp)
        elif k == "attention":
            y = _attention_ref(deq(op.inputs[0]), deq(op.inputs[1]),
                               deq(op.inputs[2]), deq(op.inputs[3]), a)
            vals[out] = quantize(y, out_qp)
        elif k == "kvappend":
            y = _kvappend_ref(deq(op.inputs[0]), deq(op.inputs[1]),
                              deq(op.inputs[2]))
            vals[out] = quantize(y, out_qp)
        elif k == "concat":
            y = np.concatenate([deq(i) for i in op.inputs], axis=2)
            vals[out] = quantize(y, out_qp)
        elif k == "split":
            parts = np.split(deq(op.inputs[0]), a["sections"], axis=2)
            for o, p in zip(op.outputs, parts):
                vals[o] = quantize(p, qm.qp(o))
        else:  # pragma: no cover
            raise NotImplementedError(k)
    return vals
