"""Quantized program replay — int8/int4 execution semantics.

Plugs into :func:`repro.core.executor.execute` via the
:class:`~repro.core.executor.ExecSemantics` hook: the replay loop (DMA
residency, bank ledger, tile gathers) is unchanged, but DRAM holds the
*stored integer values* (int8 activations, int8/unpacked-int4 weights,
int32 biases), each compute step runs the integer kernels of
:mod:`repro.quant.ptq` on its row/channel window, and model outputs are
checked two ways:

  * **exactness** against :func:`quantized_reference_execute` — the tile
    decomposition must reproduce the quantized oracle to within one
    output quantization step (int accumulation is exact; the float
    rescale epilogue is elementwise, so a one-step tolerance only covers
    rounding-boundary flips);
  * **accuracy** against the float32 oracle — callers compare the
    dequantized outputs within the *calibrated tolerance*
    (:meth:`QuantSemantics.float_tolerance`), which is the quantization
    granularity the chosen qparams imply, not an arbitrary epsilon.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.executor import ExecSemantics, _TcmState, gather_window
from repro.core.ir import (Graph, Op, _apply_act, _attention_ref,
                           _kvappend_ref, _layernorm_ref, _softmax_ref)
from repro.core.tiling import TilingResult, in_row_range

from .ptq import (QuantizedModel, q_avgpool, q_conv, q_fc,
                  q_global_avgpool, q_matmul, q_maxpool,
                  quantized_reference_execute)
from .qparams import dequantize, quantize


class QuantSemantics(ExecSemantics):
    """Integer execution semantics for a :class:`QuantizedModel`."""

    name = "int8"

    def __init__(self, qm: QuantizedModel, atol_steps: float = 1.5,
                 float_atol_steps: float = 4.0):
        self.qm = qm
        self.atol_steps = atol_steps          # vs the quantized oracle
        # vs the float oracle: int4 weights carry 16x the quantization
        # granularity of int8, so the calibrated band widens accordingly
        if qm.weight_dtype == "int4":
            float_atol_steps *= 16.0
        self.float_atol_steps = float_atol_steps
        self._qref: Optional[Dict[str, np.ndarray]] = None

    # -- artifact metadata round trip ---------------------------------------
    def meta(self) -> Dict[str, object]:
        """Everything a persisted artifact needs to rebuild *these*
        semantics (tolerances included) next to the stored qparams."""
        return {"precision": self.name,
                "weight_dtype": self.qm.weight_dtype,
                "atol_steps": self.atol_steps,
                "float_atol_steps": self.float_atol_steps}

    @classmethod
    def from_meta(cls, qm: QuantizedModel,
                  meta: Dict[str, object]) -> "QuantSemantics":
        sem = cls(qm, atol_steps=float(meta.get("atol_steps", 1.5)))
        # float_atol_steps was already widened for int4 at save time;
        # restore it verbatim rather than re-deriving
        if "float_atol_steps" in meta:
            sem.float_atol_steps = float(meta["float_atol_steps"])
        return sem

    # -- plan lowering hooks (repro.core.execplan) --------------------------
    def plan_lowerer(self):
        """Quantized plans coalesce to one fused integer kernel per op
        (integer accumulation is order-exact, so whole-op kernels
        reproduce the per-step interpreter's stored integers)."""
        import functools

        from .execplan import lower_quant_steps
        return functools.partial(lower_quant_steps, self.qm)

    def plan_dtype(self, tensor) -> np.dtype:
        # activations are stored int8 (the same bytes the interpreter's
        # DRAM/TCM hold); params never enter the arena — they are baked
        # into the kernels at lowering time.  Quantization-exempt
        # tensors (sequence-position operands) stay float32.
        if tensor.qparams is None:
            return np.dtype(np.float32)
        return np.dtype(np.int8)

    def encode_input(self, name: str, arr) -> np.ndarray:
        if self.qm.graph.tensors[name].qparams is None:
            return np.asarray(arr, np.float32)
        return quantize(np.asarray(arr, np.float32), self.qm.qp(name))

    def plan_parity_tol(self, tensor: str) -> float:
        if self.qm.graph.tensors[tensor].qparams is None:
            return 1e-6
        return self._scale(tensor) + 1e-7   # one output quant step

    # -- replay hooks -------------------------------------------------------
    def dram_init(self, g: Graph, inputs, weights) -> Dict[str, np.ndarray]:
        dram: Dict[str, np.ndarray] = {}
        for t in g.tensors.values():
            if t.kind == "input":
                dram[t.name] = self.encode_input(
                    t.name, np.asarray(inputs[t.name], np.float32))
            elif t.is_param:
                dram[t.name] = self.qm.qweights[t.name]
        return dram

    def run_step(self, g: Graph, tiling: TilingResult, tcm: _TcmState,
                 op: Op, r0: int, r1: int, axis: str
                 ) -> Dict[str, np.ndarray]:
        return _run_qstep(self.qm, g, tiling, tcm, op, r0, r1, axis)

    def reference(self, g: Graph, inputs, weights) -> Dict[str, np.ndarray]:
        self._qref = quantized_reference_execute(self.qm, inputs)
        return {t.name: dequantize(self._qref[t.name], self.qm.qp(t.name))
                for t in g.outputs}

    def decode(self, tensor: str, arr: np.ndarray) -> np.ndarray:
        return dequantize(arr, self.qm.qp(tensor))

    def tolerance(self, tensor: str, want, atol: float) -> float:
        return self.atol_steps * self._scale(tensor) + 1e-7

    # -- calibrated tolerance vs the float oracle ---------------------------
    def _scale(self, tensor: str) -> float:
        return float(np.max(np.atleast_1d(self.qm.qp(tensor).scale)))

    def float_tolerance(self, tensor: str) -> float:
        """Accepted |dequantized - float oracle| for one model output.

        Calibrated: 2x the worst error this PTQ exhibited on its own
        calibration set (measure_quant_error) when available — the
        honest depth-aware bound — with a floor of a few steps of the
        output quantization grid (requant rounding)."""
        floor = self.float_atol_steps * self._scale(tensor) + 1e-6
        cal = self.qm.calib_error.get(tensor)
        if cal is not None and cal > 0:
            return max(floor, 2.0 * cal)
        return floor


# --------------------------------------------------------------------------
# Per-step integer computation (mirrors core executor._run_step)
# --------------------------------------------------------------------------


def _run_qstep(qm: QuantizedModel, g: Graph, tiling: TilingResult,
               tcm: _TcmState, op: Op, r0: int, r1: int, axis: str
               ) -> Dict[str, np.ndarray]:
    a = op.attrs
    k = op.kind
    out0 = g.tensors[op.outputs[0]]
    H = out0.shape[0] if len(out0.shape) == 3 else 1

    if axis == "chan":
        c0, c1 = r0, r1
        rr0, rr1 = 0, H
    else:
        c0 = 0
        c1 = out0.shape[-1]
        rr0, rr1 = r0, r1

    def rows_of(x, lo, hi):
        return tcm.gather_rows(tiling, x.name, lo, hi)

    def deq(x, arr):
        return dequantize(arr, qm.qp(x.name))

    out_qp = qm.qp(op.outputs[0])

    if k in ("conv", "dwconv"):
        x = g.act_inputs(op)[0]
        kh = a["k"][0]
        s = a["stride"]
        pt, pb, pl, pr = a["pad"]
        win, top, bot = gather_window(tcm, tiling, x, rr0, rr1, kh, s, pt)
        w_q = tcm.gather_param(tiling, op.inputs[1], c0, c1)
        w_qp = qm.qp(op.inputs[1])
        if w_qp.per_channel and axis == "chan":
            w_qp = _slice_qp(w_qp, c0, c1)
        if k == "dwconv" and axis == "chan":
            win = win[:, :, c0:c1]
        bias_q = None
        if len(op.inputs) > 2:
            bias_q = tcm.gather_param(tiling, op.inputs[2], c0, c1)
        y = q_conv(win, qm.qp(x.name), w_q, w_qp, bias_q, s,
                   (top, bot, pl, pr), k == "dwconv",
                   a.get("act", "none"), out_qp)
    elif k == "fc":
        x = g.act_inputs(op)[0]
        xin = rows_of(x, 0, x.shape[0] if len(x.shape) == 3 else 1)
        w_q = tcm.gather_param(tiling, op.inputs[1], c0, c1)[:, 0, 0, :]
        w_qp = qm.qp(op.inputs[1])
        if w_qp.per_channel and axis == "chan":
            w_qp = _slice_qp(w_qp, c0, c1)
        bias_q = None
        if len(op.inputs) > 2:
            bias_q = tcm.gather_param(tiling, op.inputs[2], c0, c1)
        y = q_fc(xin, qm.qp(x.name), w_q, w_qp, bias_q,
                 a.get("act", "none"), out_qp).reshape(1, 1, -1)
    elif k == "add":
        xs = []
        for x in g.act_inputs(op):
            ih = x.shape[0] if len(x.shape) == 3 else 1
            lo, hi = in_row_range(op, rr0, rr1, ih)
            xs.append(deq(x, rows_of(x, lo, hi)))
        y = quantize(_apply_act(xs[0] + xs[1], a.get("act", "none")),
                     out_qp)
    elif k == "mul":
        xs = []
        for x in g.act_inputs(op):
            ih = x.shape[0] if len(x.shape) == 3 else 1
            lo, hi = in_row_range(op, rr0, rr1, ih)
            xs.append(deq(x, rows_of(x, lo, hi)))
        y = quantize(xs[0] * xs[1], out_qp)
    elif k == "scalar":
        x = g.act_inputs(op)[0]
        xv = deq(x, rows_of(x, rr0, rr1))
        v = a["value"]
        y = quantize({"add": xv + v, "mul": xv * v,
                      "div": xv / v}[a["op"]], out_qp)
    elif k == "act":
        x = g.act_inputs(op)[0]
        y = quantize(_apply_act(deq(x, rows_of(x, rr0, rr1)), a["act"]),
                     out_qp)
    elif k in ("maxpool", "avgpool"):
        x = g.act_inputs(op)[0]
        ih = x.shape[0]
        if k == "avgpool" and a["k"] == 0:
            win = rows_of(x, 0, ih)
            y = q_global_avgpool(win, qm.qp(x.name), out_qp)
        else:
            kk, s = a["k"], a["stride"]
            pt, pb, pl, pr = a["pad"]
            win, top, bot = gather_window(tcm, tiling, x, rr0, rr1,
                                          kk, s, pt)
            fn = q_maxpool if k == "maxpool" else q_avgpool
            y = fn(win, kk, s, (top, bot, pl, pr), qm.qp(x.name), out_qp)
    elif k == "resize":
        f = a["factor"]
        lo, hi = rr0 // f, (rr1 + f - 1) // f
        x = g.act_inputs(op)[0]
        win = rows_of(x, lo, hi)
        rep = np.repeat(np.repeat(win, f, axis=0), f, axis=1)
        rep = rep[rr0 - lo * f: rr1 - lo * f]
        y = quantize(deq(x, rep), out_qp)
    elif k == "concat":
        xs = [deq(x, rows_of(x, rr0, rr1)) for x in g.act_inputs(op)]
        y = quantize(np.concatenate(xs, axis=2), out_qp)
    elif k == "split":
        x = g.act_inputs(op)[0]
        xin = deq(x, rows_of(x, rr0, rr1))
        parts = np.split(xin, a["sections"], axis=2)
        return {o: quantize(p, qm.qp(o))
                for o, p in zip(op.outputs, parts)}
    elif k == "matmul":
        x = g.act_inputs(op)[0]
        xin = rows_of(x, rr0, rr1)
        w_q = tcm.gather_param(tiling, op.inputs[1], c0, c1)[:, 0, 0, :]
        w_qp = qm.qp(op.inputs[1])
        if w_qp.per_channel and axis == "chan":
            w_qp = _slice_qp(w_qp, c0, c1)
        bias_q = None
        if len(op.inputs) > 2:
            bias_q = tcm.gather_param(tiling, op.inputs[2], c0, c1)
        y = q_matmul(xin, qm.qp(x.name), w_q, w_qp, bias_q,
                     a.get("act", "none"), out_qp)
    elif k == "layernorm":
        x = g.act_inputs(op)[0]
        xv = deq(x, rows_of(x, rr0, rr1))
        nc = g.tensors[op.inputs[1]].shape[0]
        gam = tcm.gather_param(tiling, op.inputs[1], 0, nc)
        bet = tcm.gather_param(tiling, op.inputs[2], 0, nc)
        y = quantize(_layernorm_ref(xv, gam, bet, a["eps"]), out_qp)
    elif k == "softmax":
        x = g.act_inputs(op)[0]
        y = quantize(_softmax_ref(deq(x, rows_of(x, rr0, rr1))), out_qp)
    elif k == "attention":
        qx, kc, vc, ps = g.act_inputs(op)
        qin = deq(qx, rows_of(qx, rr0, rr1))
        kin = deq(kc, rows_of(kc, 0, kc.shape[0]))
        vin = deq(vc, rows_of(vc, 0, vc.shape[0]))
        pin = rows_of(ps, 0, 1)          # float32, quantization-exempt
        y = quantize(_attention_ref(qin, kin, vin, pin, a,
                                    q0=rr0, s_total=qx.shape[0]), out_qp)
    elif k == "kvappend":
        cx, nx, ps = g.act_inputs(op)
        cin = deq(cx, rows_of(cx, 0, cx.shape[0]))
        nin = deq(nx, rows_of(nx, 0, nx.shape[0]))
        pin = rows_of(ps, 0, 1)
        y = quantize(_kvappend_ref(cin, nin, pin), out_qp)[rr0:rr1]
    else:  # pragma: no cover
        raise NotImplementedError(k)
    return {op.outputs[0]: y}


def _slice_qp(qp, c0: int, c1: int):
    from repro.core.ir import QParams
    return QParams(np.atleast_1d(qp.scale)[c0:c1],
                   np.atleast_1d(qp.zero_point)[c0:c1],
                   bits=qp.bits, axis=qp.axis)
