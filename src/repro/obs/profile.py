"""Execution profiler: traced wall time vs the cost model, per op.

The paper's argument is that *sustained utilization* — not peak TOPS —
decides real NPU performance.  The compiler's cost model predicts a
schedule (cycles per compute job, DDR bytes per transfer); the replay
engine then actually executes it.  This module correlates the two:

* **modeled** — what the schedule claims: latency, compute occupancy
  (compute-busy cycles / total cycles, i.e. how well DAE overlap hid
  the DMA), DDR traffic and the bandwidth it implies at modeled speed;
* **measured** — what one timed :class:`~repro.core.execplan.ExecPlan`
  replay actually took, per request, with per-kernel step times;
* **per-op correlation** — each op's share of modeled cycles vs its
  share of measured kernel time.  The ``skew`` column (measured share /
  modeled share) is the actionable number: ops with skew >> 1 are the
  ones the cost model under-prices on this backend and where tuning
  (or model recalibration) pays first.

``CompiledModel.profile()`` is the entry point; the report renders as
an aligned text table and round-trips through ``as_dict()`` for
benches and dashboards.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


def _op_of_label(label: str) -> str:
    """Kernel label -> op name.  Float lowering labels steps
    ``op[r0:r1@axis]``; quant lowering labels fused kernels ``op@op``."""
    return label.split("[", 1)[0].split("@", 1)[0]


@dataclass
class OpProfile:
    op: str
    kind: str
    kernels: int                    # lowered kernels attributed to the op
    measured_ms: float              # per-request wall time in its kernels
    modeled_cycles: int
    macs: int
    measured_share: float = 0.0
    modeled_share: float = 0.0

    @property
    def skew(self) -> float:
        """measured share / modeled share — >1 means the cost model
        under-prices this op on the measuring backend."""
        if self.modeled_share <= 0.0:
            return float("inf") if self.measured_share > 0 else 1.0
        return self.measured_share / self.modeled_share


@dataclass
class ProfileReport:
    model: str
    precision: str
    batch: int
    runs: int
    modeled: Dict[str, float]       # the cost model's claims
    measured: Dict[str, float]      # the timed replay's reality
    ops: List[OpProfile] = field(default_factory=list)
    # per-KIND rollup of ``ops`` (conv, matmul, attention, ...): shares
    # sum over the kind's ops, skew recomputed from the summed shares —
    # the one-line answer to "is the cost model off on attention, or on
    # this one attention op?"
    kinds: List[OpProfile] = field(default_factory=list)

    @staticmethod
    def _row(o: OpProfile) -> Dict:
        return {
            "op": o.op, "kind": o.kind, "kernels": o.kernels,
            "measured_ms": round(o.measured_ms, 6),
            "modeled_cycles": o.modeled_cycles, "macs": o.macs,
            "measured_share": round(o.measured_share, 4),
            "modeled_share": round(o.modeled_share, 4),
            "skew": round(o.skew, 3) if o.skew != float("inf")
            else None,
        }

    def as_dict(self) -> Dict:
        return {
            "model": self.model, "precision": self.precision,
            "batch": self.batch, "runs": self.runs,
            "modeled": dict(self.modeled),
            "measured": dict(self.measured),
            "ops": [self._row(o) for o in self.ops],
            "kinds": [self._row(o) for o in self.kinds],
        }

    def render(self, top: int = 12) -> str:
        mo, me = self.modeled, self.measured
        lines = [
            f"Profile {self.model!r} [{self.precision}]  batch "
            f"{self.batch}, best of {self.runs} run(s)",
            f"  modeled   {mo['latency_ms']:.3f} ms/req  "
            f"({mo['ticks']:.0f} ticks, "
            f"{100 * mo['compute_occupancy']:.0f}% compute-occupied, "
            f"{100 * mo['utilization']:.0f}% of peak TOPS)",
            f"  modeled   DDR {mo['ddr_mb']:.2f} MB/req -> "
            f"{mo['ddr_gb_s']:.2f} GB/s at modeled speed",
            f"  measured  {me['wall_ms_per_request']:.3f} ms/req "
            f"({me['kernel_ms_per_request']:.3f} ms in "
            f"{me['kernels']:.0f} kernels)  "
            f"sim {me['sim_tops']:.4f} TOPS "
            f"({100 * me['sim_utilization']:.2f}% of peak)",
            f"  measured  DDR bandwidth implied {me['ddr_gb_s']:.3f} "
            f"GB/s  |  model-vs-actual speed x"
            f"{me['model_vs_actual']:.1f}",
            f"  {'op':<28}{'kind':<9}{'meas ms':>9}{'meas %':>8}"
            f"{'model %':>9}{'skew':>7}",
        ]
        for o in self.ops[:top]:
            skew = f"{o.skew:6.2f}" if o.skew != float("inf") else "   inf"
            lines.append(
                f"  {o.op:<28}{o.kind:<9}{o.measured_ms:9.3f}"
                f"{100 * o.measured_share:7.1f}%"
                f"{100 * o.modeled_share:8.1f}%{skew:>7}")
        if len(self.ops) > top:
            rest = sum(o.measured_ms for o in self.ops[top:])
            lines.append(f"  ... {len(self.ops) - top} more op(s), "
                         f"{rest:.3f} ms")
        if self.kinds:
            lines.append(
                f"  {'by kind':<28}{'kernels':<9}{'meas ms':>9}"
                f"{'meas %':>8}{'model %':>9}{'skew':>7}")
            for o in self.kinds:
                skew = (f"{o.skew:6.2f}" if o.skew != float("inf")
                        else "   inf")
                lines.append(
                    f"  {o.op:<28}{o.kernels:<9}{o.measured_ms:9.3f}"
                    f"{100 * o.measured_share:7.1f}%"
                    f"{100 * o.modeled_share:8.1f}%{skew:>7}")
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()

    __repr__ = __str__


def profile_model(model, inputs=None, batch: int = 8, runs: int = 3,
                  warmup: int = 1) -> ProfileReport:
    """Timed, per-kernel-instrumented plan replay of ``model`` (a
    :class:`repro.api.CompiledModel`), correlated against its cost
    model.  ``inputs`` is one sample feed (dict or array); zeros when
    omitted.  The best (min total) of ``runs`` replays is reported —
    per-request numbers divide by ``batch``."""
    g = model.graph
    if inputs is None:
        feed = {t.name: np.zeros(t.shape, dtype=np.float32)
                for t in g.inputs}
    else:
        feed = model._normalize(inputs)
    stacked = {k: np.repeat(np.asarray(v, dtype=np.float32)[None],
                            batch, axis=0)
               for k, v in feed.items()}
    plan = model.plan_for(batch)
    for _ in range(max(0, warmup)):
        plan.run(stacked, n=batch)

    best_wall = float("inf")
    best_steps: List = []
    for _ in range(max(1, runs)):
        step_times: List = []
        t0 = time.monotonic()
        plan.run(stacked, n=batch, step_times=step_times)
        wall = time.monotonic() - t0
        if wall < best_wall:
            best_wall, best_steps = wall, step_times

    prog = model.program
    stats = prog.stats()
    lat_cycles = prog.latency_cycles()
    compute_cycles = sum(t.l_c() for t in prog.ticks)
    modeled_s = lat_cycles / model.cfg.freq_hz
    ddr = prog.ddr_bytes()
    modeled = {
        "latency_ms": stats["latency_ms"],
        "ticks": stats["ticks"],
        "gmacs": stats["gmacs"],
        "ddr_mb": stats["ddr_mb"],
        "effective_tops": stats["effective_tops"],
        "utilization": stats["utilization"],
        "compute_occupancy": (compute_cycles / lat_cycles
                              if lat_cycles else 0.0),
        "ddr_gb_s": ddr / modeled_s / 1e9 if modeled_s else 0.0,
    }

    wall_per_req = best_wall / batch
    kernel_s = sum(dt for _, dt in best_steps)
    total_macs = prog.total_macs()
    measured = {
        "wall_ms_per_request": wall_per_req * 1e3,
        "kernel_ms_per_request": kernel_s / batch * 1e3,
        "kernels": float(len(best_steps)),
        "sim_tops": (2 * total_macs / wall_per_req / 1e12
                     if wall_per_req else 0.0),
        "sim_utilization": (2 * total_macs / wall_per_req / 1e12
                            / model.cfg.peak_tops if wall_per_req
                            else 0.0),
        "ddr_gb_s": ddr / wall_per_req / 1e9 if wall_per_req else 0.0,
        # how many x slower the measuring backend runs than the modeled
        # NPU — the correlation constant between the two columns
        "model_vs_actual": (wall_per_req / modeled_s
                            if modeled_s else 0.0),
    }

    # -- per-op attribution -------------------------------------------------
    cyc: Dict[str, int] = {}
    macs: Dict[str, int] = {}
    for cj, _, _, _ in prog.compute_steps():
        cyc[cj.op_name] = cyc.get(cj.op_name, 0) + cj.cycles
        macs[cj.op_name] = macs.get(cj.op_name, 0) + cj.macs
    meas: Dict[str, float] = {}
    nker: Dict[str, int] = {}
    for label, dt in best_steps:
        op = _op_of_label(label)
        meas[op] = meas.get(op, 0.0) + dt
        nker[op] = nker.get(op, 0) + 1
    total_cyc = sum(cyc.values()) or 1
    total_meas = sum(meas.values()) or 1.0
    kinds = {op.name: op.kind for op in g.ops}
    ops: List[OpProfile] = []
    for op in set(cyc) | set(meas):
        o = OpProfile(
            op=op, kind=kinds.get(op, "?"), kernels=nker.get(op, 0),
            measured_ms=meas.get(op, 0.0) / batch * 1e3,
            modeled_cycles=cyc.get(op, 0), macs=macs.get(op, 0))
        o.measured_share = meas.get(op, 0.0) / total_meas
        o.modeled_share = cyc.get(op, 0) / total_cyc
        ops.append(o)
    ops.sort(key=lambda o: o.measured_ms, reverse=True)

    by_kind: Dict[str, OpProfile] = {}
    for o in ops:
        k = by_kind.get(o.kind)
        if k is None:
            k = by_kind[o.kind] = OpProfile(
                op=o.kind, kind=o.kind, kernels=0, measured_ms=0.0,
                modeled_cycles=0, macs=0)
        k.kernels += o.kernels
        k.measured_ms += o.measured_ms
        k.modeled_cycles += o.modeled_cycles
        k.macs += o.macs
        k.measured_share += o.measured_share
        k.modeled_share += o.modeled_share
    kind_rows = sorted(by_kind.values(),
                       key=lambda o: o.measured_ms, reverse=True)

    return ProfileReport(model=model.name, precision=model.precision,
                         batch=batch, runs=max(1, runs),
                         modeled=modeled, measured=measured, ops=ops,
                         kinds=kind_rows)
