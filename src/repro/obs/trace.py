"""Span-based tracer: thread-safe ring buffer, Chrome trace-event export.

Design constraints, in priority order:

1. **~zero cost when disabled.**  Production code guards every
   instrumentation point with one module attribute load
   (``trace.active() is None``); nothing else runs.  Hot loops (the
   ``ExecPlan`` kernel sequence) hoist that check out of the loop.
2. **Bounded memory when enabled.**  Completed spans land in a
   ``deque(maxlen=capacity)`` ring — recording never allocates beyond
   the ring, and a long soak keeps the most recent spans.
3. **Cross-thread attribution.**  Every span records its thread id and
   name; request spans additionally carry the **trace id** minted at
   ``Session.submit()``, so one request can be followed from the
   submitting thread through the worker that served it.

The export (:meth:`Tracer.chrome_trace`) is the Chrome trace-event JSON
array format — complete (``"X"``) spans, instant (``"i"``) events,
thread-name metadata and flow arrows stitching each trace id across
threads — loadable in Perfetto (ui.perfetto.dev) or chrome://tracing.

Recording uses ``time.monotonic()`` (the serving runtime's latency
clock), *not* the chaos-skewable deadline clock: traces measure what
actually happened, fault injection included.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

#: event tuple layout (kept a plain tuple — recording is the hot path):
#: (name, cat, t0, t1_or_None, thread_id, thread_name, trace_id, args)
Event = Tuple[str, str, float, Optional[float], int, str,
              Optional[int], Optional[dict]]

_ids = itertools.count(1)


def new_trace_id() -> int:
    """Mint a process-unique request trace id (cheap, always-on: ids
    are assigned at submit time whether or not tracing is enabled, so
    enabling mid-run attributes in-flight requests correctly)."""
    return next(_ids)


class Tracer:
    """One armed span ring buffer.

    ``complete``/``instant`` are safe from any thread: appends to a
    bounded deque are atomic under the GIL, so the record path takes no
    lock.  ``plan_steps`` controls whether :meth:`ExecPlan.run
    <repro.core.execplan.ExecPlan.run>` emits one span per lowered
    kernel (the finest — and by far the highest-volume — level)."""

    def __init__(self, capacity: int = 131072, plan_steps: bool = True):
        self.capacity = int(capacity)
        self.plan_steps = bool(plan_steps)
        self.epoch = time.monotonic()
        self._buf: "deque[Event]" = deque(maxlen=self.capacity)

    # -- recording (hot) ----------------------------------------------------
    @staticmethod
    def clock() -> float:
        return time.monotonic()

    def complete(self, name: str, cat: str, t0: float,
                 t1: Optional[float] = None,
                 trace_id: Optional[int] = None,
                 args: Optional[dict] = None) -> None:
        """Record a finished span [t0, t1] (t1 defaults to now)."""
        th = threading.current_thread()
        self._buf.append((name, cat, t0,
                          time.monotonic() if t1 is None else t1,
                          th.ident or 0, th.name, trace_id, args))

    def instant(self, name: str, cat: str = "",
                trace_id: Optional[int] = None,
                args: Optional[dict] = None) -> None:
        """Record a zero-duration event (state transitions: breaker
        trips, worker recycles, cache tier outcomes)."""
        th = threading.current_thread()
        self._buf.append((name, cat, time.monotonic(), None,
                          th.ident or 0, th.name, trace_id, args))

    @contextmanager
    def span(self, name: str, cat: str = "",
             trace_id: Optional[int] = None, **args):
        """Context-manager convenience for non-hot paths."""
        t0 = time.monotonic()
        try:
            yield self
        finally:
            self.complete(name, cat, t0, trace_id=trace_id,
                          args=args or None)

    # -- inspection ---------------------------------------------------------
    def events(self) -> List[Event]:
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def clear(self) -> None:
        self._buf.clear()

    # -- export -------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """The ring's contents as a Chrome trace-event JSON document:
        ``{"traceEvents": [...], "displayTimeUnit": "ms"}``.  Spans are
        complete (``"X"``) events with microsecond ``ts``/``dur``
        relative to the tracer's epoch; instants are ``"i"`` events;
        thread names ship as ``"M"`` metadata; and every trace id seen
        on two or more threads gets flow (``"s"``/``"t"``/``"f"``)
        arrows so Perfetto draws the request's hop from the submitting
        thread to the worker that served it."""
        pid = os.getpid()
        evs: List[dict] = []
        tid_names: Dict[int, str] = {}
        by_id: Dict[int, List[dict]] = {}
        for name, cat, t0, t1, tid, tname, trace_id, args in self._buf:
            tid_names[tid] = tname
            if cat.startswith("async:") and t1 is not None:
                # cross-thread interval (e.g. queue wait: starts on the
                # submitting thread, ends on the worker): an async
                # begin/end pair keyed by trace id — these render in
                # their own track and never distort thread nesting
                base = {"name": name, "cat": cat[6:], "pid": pid,
                        "tid": tid, "id": trace_id or 0}
                b = dict(base, ph="b",
                         ts=round((t0 - self.epoch) * 1e6, 3))
                if args:
                    b["args"] = dict(args)
                evs.append(b)
                evs.append(dict(base, ph="e",
                                ts=round((t1 - self.epoch) * 1e6, 3)))
                continue
            d: dict = {"name": name, "cat": cat or "repro", "pid": pid,
                       "tid": tid,
                       "ts": round((t0 - self.epoch) * 1e6, 3)}
            if t1 is None:
                d["ph"] = "i"
                d["s"] = "t"
            else:
                d["ph"] = "X"
                d["dur"] = round(max(0.0, t1 - t0) * 1e6, 3)
            a = dict(args) if args else {}
            if trace_id is not None:
                a["trace_id"] = trace_id
                if d["ph"] == "X":
                    by_id.setdefault(trace_id, []).append(d)
            if a:
                d["args"] = a
            evs.append(d)
        flows: List[dict] = []
        for trace_id, seq in by_id.items():
            if len(seq) < 2 or len({d["tid"] for d in seq}) < 2:
                continue
            seq.sort(key=lambda d: d["ts"])
            last = len(seq) - 1
            for i, d in enumerate(seq):
                f = {"name": "request", "cat": "flow", "id": trace_id,
                     "pid": pid, "tid": d["tid"],
                     # nudge inside the span so the arrow binds to it
                     "ts": round(d["ts"] + 0.001, 3),
                     "ph": "s" if i == 0 else ("f" if i == last else "t")}
                if f["ph"] == "f":
                    f["bp"] = "e"
                flows.append(f)
        meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                 "args": {"name": tname}}
                for tid, tname in sorted(tid_names.items())]
        return {"traceEvents": meta + evs + flows,
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write :meth:`chrome_trace` to ``path`` (open the file in
        ui.perfetto.dev or chrome://tracing)."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


# --------------------------------------------------------------------------
# Module-level switchboard (what the instrumented code consults)
# --------------------------------------------------------------------------

_LOCK = threading.Lock()
_TRACER: Optional[Tracer] = None


def enable(capacity: int = 131072, plan_steps: bool = True) -> Tracer:
    """Arm a fresh global tracer (replacing any armed one) and return
    it.  ``plan_steps=False`` keeps serving/compile spans but skips the
    per-kernel level (the highest-volume events)."""
    global _TRACER
    with _LOCK:
        _TRACER = Tracer(capacity=capacity, plan_steps=plan_steps)
        return _TRACER


def disable() -> Optional[Tracer]:
    """Disarm tracing; returns the tracer (with its recorded spans) so
    callers can still export after disabling."""
    global _TRACER
    with _LOCK:
        t, _TRACER = _TRACER, None
        return t


def active() -> Optional[Tracer]:
    """The armed tracer, or None — the one-load guard every
    instrumentation point uses."""
    return _TRACER


@contextmanager
def maybe_span(name: str, cat: str = "",
               trace_id: Optional[int] = None, **args):
    """Span when tracing is armed, no-op otherwise (cool paths only —
    hot loops should hoist an ``active()`` check instead)."""
    t = _TRACER
    if t is None:
        yield None
        return
    t0 = time.monotonic()
    try:
        yield t
    finally:
        t.complete(name, cat, t0, trace_id=trace_id, args=args or None)


def instant(name: str, cat: str = "", trace_id: Optional[int] = None,
            args: Optional[dict] = None) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, cat, trace_id=trace_id, args=args)


@contextmanager
def session(capacity: int = 131072, plan_steps: bool = True):
    """``with trace.session() as t: ...`` — arm, run, disarm."""
    t = enable(capacity=capacity, plan_steps=plan_steps)
    try:
        yield t
    finally:
        with _LOCK:
            global _TRACER
            if _TRACER is t:
                _TRACER = None


# --------------------------------------------------------------------------
# Cross-process merge (worker-process tracers -> one document)
# --------------------------------------------------------------------------

def merge_chrome_traces(parent_doc: dict, parent_epoch: float,
                        children) -> dict:
    """Merge worker processes' trace documents into the parent's.

    ``children`` is an iterable of ``(child_epoch, child_doc)`` pairs
    (what :meth:`repro.runtime.procpool.ProcPool.collect_child_traces`
    returns).  Each child's event timestamps are relative to its own
    tracer epoch; ``time.monotonic()`` is CLOCK_MONOTONIC — one
    system-wide clock shared by every process on the host — so rebasing
    by the epoch delta puts all events on the parent's timeline.  Each
    child keeps its own ``pid``, so per-(pid, tid) span nesting (what
    :func:`validate_chrome_trace` checks) is preserved."""
    evs = list(parent_doc.get("traceEvents", ()))
    for child_epoch, child_doc in children:
        shift_us = (float(child_epoch) - float(parent_epoch)) * 1e6
        for d in (child_doc or {}).get("traceEvents", ()):
            d = dict(d)
            if "ts" in d:
                d["ts"] = round(d["ts"] + shift_us, 3)
            evs.append(d)
    out = {k: v for k, v in parent_doc.items() if k != "traceEvents"}
    out.setdefault("displayTimeUnit", "ms")
    out["traceEvents"] = evs
    return out


# --------------------------------------------------------------------------
# Schema validation (tests, benches and CI all assert through this)
# --------------------------------------------------------------------------

def validate_chrome_trace(doc: dict) -> List[str]:
    """Structural validation of a Chrome trace-event document; returns
    a list of problems (empty = valid).  Checks the JSON object form,
    per-phase required keys, and — per thread — that complete spans
    nest properly (no partial overlap), which is what makes the
    Perfetto flame view meaningful."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be an object with a traceEvents array"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents must be an array"]
    spans_by_tid: Dict[Tuple[int, int], List[Tuple[float, float, str]]] = {}
    for i, d in enumerate(evs):
        if not isinstance(d, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = d.get("ph")
        if ph not in ("X", "i", "I", "M", "s", "t", "f", "b", "e"):
            problems.append(f"event {i}: unsupported ph {ph!r}")
            continue
        if ph == "M":
            continue
        for k in ("name", "pid", "tid", "ts"):
            if k not in d:
                problems.append(f"event {i} ({d.get('name')!r}): "
                                f"missing {k!r}")
        if ph == "X":
            if "dur" not in d or d["dur"] < 0:
                problems.append(f"event {i} ({d.get('name')!r}): "
                                f"X event needs dur >= 0")
            else:
                spans_by_tid.setdefault(
                    (d.get("pid", 0), d.get("tid", 0)), []).append(
                    (d["ts"], d["ts"] + d["dur"], d.get("name", "?")))
        if ph in ("s", "t", "f", "b", "e") and "id" not in d:
            problems.append(f"event {i}: flow/async event missing id")
    for (pid, tid), spans in spans_by_tid.items():
        # sort outermost-first; a proper nesting never partially
        # overlaps the enclosing span on its own thread
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: List[Tuple[float, float, str]] = []
        for t0, t1, name in spans:
            while stack and t0 >= stack[-1][1]:
                stack.pop()
            if stack and t1 > stack[-1][1] + 1e-6:
                problems.append(
                    f"tid {tid}: span {name!r} [{t0:.1f},{t1:.1f}] "
                    f"partially overlaps {stack[-1][2]!r} "
                    f"[{stack[-1][0]:.1f},{stack[-1][1]:.1f}]")
                continue
            stack.append((t0, t1, name))
    return problems
