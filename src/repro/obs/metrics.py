"""Metrics registry: counters, gauges, log-bucketed histograms with
label sets, and Prometheus-style text exposition.

One registry per :class:`repro.api.Session` (the serving runtime's
counters, the program cache's tier stats and the pool's worker health
all register here); ``Session.metrics()`` renders it.  The design is a
deliberately small subset of the Prometheus client model:

* a **metric family** is created once (``registry.counter(name, help,
  labelnames)``) and is idempotent — re-requesting the same name
  returns the same family, so independent modules can share a series
  (the pool and the session both count ``repro_shed_total{model=...}``
  without coordinating).
* **children** are label-value tuples: ``family.labels(model="x")``
  returns the mutable child (a float cell, or a
  :class:`LogHistogram`); convenience forms ``family.inc(n, model=x)``
  / ``family.observe(ms, model=x)`` skip the intermediate object.
* **collectors** are callbacks run at render/snapshot time for state
  that lives elsewhere (queue depths, cache occupancy, worker health):
  they set gauges instead of every module pushing on every change.

Histograms are log-spaced (:class:`LogHistogram` — O(1) record, ~5%
quantile resolution, fixed memory; this is the serving runtime's
p50/p99 surface, absorbed from the old
``repro.runtime.serving.LatencyHistogram``) and render as Prometheus
*summaries* (quantile series + ``_sum``/``_count``).
"""
from __future__ import annotations

import math
import re
import threading
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape(v: object) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
        .replace("\n", r"\n")


def _fmt_labels(labelnames: Tuple[str, ...], values: Tuple,
                extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in zip(labelnames, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_val(v: float) -> str:
    f = float(v)
    if f != f:
        return "NaN"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


# --------------------------------------------------------------------------
# Log-spaced histogram (p50/p99 without storing samples)
# --------------------------------------------------------------------------


class LogHistogram:
    """Log-spaced histogram: O(1) record, ~5% quantile resolution,
    fixed memory.  Thread-safe.  Units are whatever you feed it (the
    serving runtime records milliseconds)."""

    def __init__(self, lo: float = 0.05, hi: float = 120_000.0,
                 per_decade: int = 48):
        self._lo = lo
        self._log_ratio = math.log(10.0) / per_decade
        self._n = int(math.log(hi / lo) / self._log_ratio) + 2
        self._counts = [0] * self._n
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    # serving-era aliases (the histogram recorded milliseconds there)
    @property
    def sum_ms(self) -> float:
        return self.sum

    @property
    def max_ms(self) -> float:
        return self.max

    def record(self, v: float) -> None:
        v = max(v, 0.0)
        idx = 0 if v <= self._lo else min(
            self._n - 1, 1 + int(math.log(v / self._lo) / self._log_ratio))
        with self._lock:
            self._counts[idx] += 1
            self.count += 1
            self.sum += v
            self.max = max(self.max, v)

    observe = record

    def percentile(self, p: float) -> float:
        """Upper edge of the bucket holding the p-th percentile (0 when
        empty)."""
        with self._lock:
            if not self.count:
                return 0.0
            target = p / 100.0 * self.count
            seen = 0
            for i, c in enumerate(self._counts):
                seen += c
                if seen >= target:
                    return self._lo * math.exp(i * self._log_ratio)
            return self.max

    def snapshot(self) -> Dict[str, float]:
        p50, p99 = self.percentile(50), self.percentile(99)
        with self._lock:
            mean = self.sum / self.count if self.count else 0.0
            return {"count": self.count, "mean_ms": mean,
                    "p50_ms": p50, "p99_ms": p99, "max_ms": self.max}


# --------------------------------------------------------------------------
# Metric families
# --------------------------------------------------------------------------


class _Family:
    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Tuple[str, ...] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: "OrderedDict[Tuple, object]" = OrderedDict()

    def _key(self, labels: Dict[str, object]) -> Tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def series(self) -> Dict[Tuple, object]:
        with self._lock:
            return dict(self._children)


class Counter(_Family):
    """Monotonically increasing float cells, one per label set."""

    kind = "counter"

    def inc(self, n: float = 1.0, **labels) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = self._key(labels)
        with self._lock:
            self._children[key] = self._children.get(key, 0.0) + n

    def value(self, **labels) -> float:
        key = self._key(labels)
        with self._lock:
            return float(self._children.get(key, 0.0))

    def total(self) -> float:
        with self._lock:
            return float(sum(self._children.values()))

    def set_total(self, v: float, **labels) -> None:
        """Collector use only: expose an externally-maintained
        monotonic total (the source counter lives elsewhere — a stats
        dict, the program cache — and render pulls it)."""
        key = self._key(labels)
        with self._lock:
            self._children[key] = float(v)


class Gauge(_Family):
    """Settable float cells, one per label set."""

    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._children[key] = float(v)

    def inc(self, n: float = 1.0, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._children[key] = self._children.get(key, 0.0) + n

    def value(self, **labels) -> float:
        key = self._key(labels)
        with self._lock:
            return float(self._children.get(key, 0.0))

    def clear(self) -> None:
        """Drop every child — collectors that enumerate live state
        (e.g. per-worker health) clear first so retired series don't
        linger forever."""
        with self._lock:
            self._children.clear()


class Histogram(_Family):
    """A family of :class:`LogHistogram` children."""

    kind = "summary"
    QUANTILES = (0.5, 0.9, 0.99)

    def __init__(self, name: str, help: str,
                 labelnames: Tuple[str, ...] = (),
                 lo: float = 0.05, hi: float = 120_000.0,
                 per_decade: int = 48):
        super().__init__(name, help, labelnames)
        self._lo, self._hi, self._pd = lo, hi, per_decade

    def labels(self, **labels) -> LogHistogram:
        key = self._key(labels)
        with self._lock:
            h = self._children.get(key)
            if h is None:
                h = self._children[key] = LogHistogram(
                    self._lo, self._hi, self._pd)
            return h

    def observe(self, v: float, **labels) -> None:
        self.labels(**labels).record(v)

    record = observe


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


class MetricsRegistry:
    """Create-once metric families + render-time collectors."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: "OrderedDict[str, _Family]" = OrderedDict()
        self._collectors: List[Callable[[], None]] = []

    def _get(self, cls, name: str, help: str,
             labelnames: Tuple[str, ...], **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if not isinstance(fam, cls) or \
                        fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind} with labels {fam.labelnames}")
                return fam
            fam = cls(name, help, tuple(labelnames), **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Tuple[str, ...] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Tuple[str, ...] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Tuple[str, ...] = (),
                  lo: float = 0.05, hi: float = 120_000.0,
                  per_decade: int = 48) -> Histogram:
        return self._get(Histogram, name, help, labelnames,
                         lo=lo, hi=hi, per_decade=per_decade)

    def register_collector(self, fn: Callable[[], None]) -> None:
        """``fn()`` runs before every render/snapshot; it should set
        gauges from live state (queue depth, cache occupancy, worker
        health) so that state is pull-based instead of push-on-change."""
        with self._lock:
            self._collectors.append(fn)

    def families(self) -> List[_Family]:
        with self._lock:
            return list(self._families.values())

    def collect(self) -> None:
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn()                    # a broken collector should be loud

    # -- output -------------------------------------------------------------
    def render(self) -> str:
        """Prometheus text exposition (version 0.0.4): ``# HELP`` /
        ``# TYPE`` headers, one sample line per child; histograms as
        summaries (quantile series + ``_sum``/``_count``)."""
        self.collect()
        out: List[str] = []
        for fam in self.families():
            if fam.help:
                out.append(f"# HELP {fam.name} {_escape(fam.help)}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            if isinstance(fam, Histogram):
                for key, h in fam.series().items():
                    for q in Histogram.QUANTILES:
                        lbl = _fmt_labels(fam.labelnames, key,
                                          f'quantile="{q}"')
                        out.append(f"{fam.name}{lbl} "
                                   f"{_fmt_val(h.percentile(100 * q))}")
                    lbl = _fmt_labels(fam.labelnames, key)
                    out.append(f"{fam.name}_sum{lbl} {_fmt_val(h.sum)}")
                    out.append(f"{fam.name}_count{lbl} {h.count}")
            else:
                series = fam.series() or ({(): 0.0}
                                          if not fam.labelnames else {})
                for key, v in series.items():
                    lbl = _fmt_labels(fam.labelnames, key)
                    out.append(f"{fam.name}{lbl} {_fmt_val(v)}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict[str, Dict]:
        """Machine-readable form: name -> {labels repr -> value /
        histogram snapshot}."""
        self.collect()
        out: Dict[str, Dict] = {}
        for fam in self.families():
            d: Dict[str, object] = {}
            for key, v in fam.series().items():
                lbl = ",".join(f"{k}={val}" for k, val in
                               zip(fam.labelnames, key)) or "_"
                d[lbl] = v.snapshot() if isinstance(v, LogHistogram) \
                    else v
            out[fam.name] = d
        return out
