"""``repro.obs`` — Neutron-Trace: the unified observability layer.

The paper's thesis is that *utilization*, not peak TOPS, decides real
NPU performance — which makes measurement a first-class subsystem, not
an afterthought.  This package is that subsystem, three pieces sharing
one design rule (~zero cost when disabled, bounded memory when enabled):

* :mod:`repro.obs.trace` — a span-based tracer.  A thread-safe ring
  buffer of completed spans; one trace ID is threaded from
  ``Session.submit()`` through queue wait, batch formation, worker
  dispatch and per-``ExecPlan``-step kernel execution, and the whole
  buffer exports as Chrome trace-event JSON loadable in Perfetto
  (``ui.perfetto.dev``) or ``chrome://tracing``.
* :mod:`repro.obs.metrics` — a metrics registry: counters, gauges and
  log-bucketed histograms with label sets, rendered as Prometheus-style
  text exposition (``Session.metrics()``).  The serving runtime's
  latency/shed/deadline/breaker/retry counters, the compiler's
  program-cache tier stats and the pool's worker health all live here
  instead of per-module private dicts.
* :mod:`repro.obs.profile` — an execution profiler correlating traced
  wall time against the cost model's predicted cycles per step:
  ``CompiledModel.profile()`` reports modeled-vs-actual occupancy, DDR
  bandwidth and the per-op kernels the cost model over/under-prices.

Quickstart::

    from repro import obs

    obs.trace.enable()                    # arm the span ring buffer
    sess.submit(...); sess.flush()
    tr = obs.trace.disable()
    tr.export("trace.json")               # open in ui.perfetto.dev

    print(sess.metrics())                 # Prometheus text exposition
    print(model.profile(batch=8))         # modeled vs actual, per op
"""
from __future__ import annotations

from . import metrics, trace
from .metrics import LogHistogram, MetricsRegistry
from .profile import ProfileReport, profile_model
from .trace import Tracer, validate_chrome_trace

__all__ = [
    "trace", "metrics",
    "Tracer", "validate_chrome_trace",
    "MetricsRegistry", "LogHistogram",
    "ProfileReport", "profile_model",
]
