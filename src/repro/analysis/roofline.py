"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` is per-partition (one chip) under SPMD, so
no extra division by chip count is applied.  Collective bytes are not in
cost_analysis; :func:`collective_bytes` parses the post-SPMD HLO and
models per-device bytes-on-wire per op:

    all-gather        out_bytes * (n-1)/n
    reduce-scatter    out_bytes * (n-1)
    all-reduce        2 * out_bytes * (n-1)/n      (ring RS+AG)
    all-to-all        out_bytes * (n-1)/n
    collective-permute out_bytes

with n = replica-group size parsed per op.  Hardware constants: TPU v5e
197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string, incl. tuple types."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    op_bytes: Dict[str, int] = field(default_factory=dict)
    op_count: Dict[str, int] = field(default_factory=dict)
    total_wire_bytes: float = 0.0

    def add(self, kind: str, wire_bytes: float) -> None:
        self.op_bytes[kind] = self.op_bytes.get(kind, 0) + int(wire_bytes)
        self.op_count[kind] = self.op_count.get(kind, 0) + 1
        self.total_wire_bytes += wire_bytes


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Parse post-SPMD HLO; model per-device bytes-on-wire per op."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\d]+)\s+"
                     r"([\w\-]+)", s)
        if not m:
            continue
        opname = m.group(2)
        kind = next((c for c in _COLLECTIVES
                     if opname == c or opname.startswith(c + "-start")
                     or opname == c + "-done"), None)
        if kind is None:
            continue
        if opname.endswith("-done"):
            continue                      # counted at -start
        out_bytes = _shape_bytes(m.group(1))
        # group size
        n = 1
        g = _GROUPS_RE.search(s)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_V2_RE.search(s)
            if g2:
                n = int(g2.group(2))
        if kind == "all-gather":
            wire = out_bytes * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            wire = out_bytes * (n - 1)
        elif kind == "all-reduce":
            wire = 2 * out_bytes * (n - 1) / max(n, 1)
        elif kind == "all-to-all":
            wire = out_bytes * (n - 1) / max(n, 1)
        else:                              # collective-permute
            wire = out_bytes
        stats.add(kind, wire)
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float                 # 6·N·D (or 6·N_active·D)
    useful_ratio: float                # MODEL_FLOPS / (HLO_FLOPs·chips)
    peak_fraction: float               # t_compute / max(all terms)
    collectives: Dict[str, int] = field(default_factory=dict)
    memory_per_chip_gb: float = 0.0
    note: str = ""

    def to_json(self) -> Dict:
        return asdict(self)


def build_roofline(arch: str, shape: str, mesh_name: str, chips: int,
                   flops_per_chip: float, bytes_per_chip: float,
                   wire_bytes_per_chip: float, model_flops: float,
                   collectives: Optional[Dict[str, float]] = None,
                   memory_per_chip: float = 0.0, note: str = ""
                   ) -> Roofline:
    t_c = flops_per_chip / PEAK_FLOPS
    t_m = bytes_per_chip / HBM_BW
    t_x = wire_bytes_per_chip / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    dom = max(t_c, t_m, t_x)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops_per_chip, bytes_per_chip=bytes_per_chip,
        wire_bytes_per_chip=wire_bytes_per_chip,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / (flops_per_chip * chips)
                      if flops_per_chip > 0 else 0.0),
        peak_fraction=(t_c / dom if dom > 0 else 0.0),
        collectives={k: int(v) for k, v in (collectives or {}).items()},
        memory_per_chip_gb=memory_per_chip / 1e9,
        note=note,
    )


def model_flops_for(arch_cfg, shape_spec) -> float:
    """6·N·D training FLOPs (dense) / 6·N_active·D (MoE); forward-only
    (2·N·D) for prefill; per-token (2·N_active) for decode."""
    n = active_params(arch_cfg)
    if shape_spec.kind == "train":
        return 6.0 * n * shape_spec.global_batch * shape_spec.seq_len
    if shape_spec.kind == "prefill":
        return 2.0 * n * shape_spec.global_batch * shape_spec.seq_len
    return 2.0 * n * shape_spec.global_batch        # one token per stream


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: shared + top_k experts)."""
    total = cfg.n_params()
    if not cfg.n_experts:
        return float(total)
    fe = cfg.moe_d_ff or cfg.d_ff
    mult = 3 if cfg.gated_mlp else 2
    n_moe_layers = cfg.n_layers - cfg.moe_layer_start
    all_experts = cfg.n_experts * mult * cfg.d_model * fe * n_moe_layers
    active_experts = cfg.top_k * mult * cfg.d_model * fe * n_moe_layers
    return float(total - all_experts + active_experts)


def format_table(rows: List[Roofline]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':9s} "
           f"{'t_comp(ms)':>10s} {'t_mem(ms)':>10s} {'t_coll(ms)':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'roofl%':>7s} {'HBM(GB)':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:9s} "
            f"{r.t_compute*1e3:10.3f} {r.t_memory*1e3:10.3f} "
            f"{r.t_collective*1e3:10.3f} {r.bottleneck:>10s} "
            f"{r.useful_ratio:7.3f} {r.peak_fraction*100:6.1f}% "
            f"{r.memory_per_chip_gb:8.2f}")
    return "\n".join(lines)
