"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
so for scan-over-layers models (every LM here) it under-reports FLOPs and
bytes by ~n_layers x.  This module re-derives the three roofline inputs
from the post-SPMD, scheduled HLO text with loop multipliers:

  * **flops** — 2 * out_elems * contracted_elems per ``dot``
    (+convolution support), multiplied through nested while trip counts;
  * **bytes** — HBM traffic modeled at fusion boundaries: operands +
    outputs of top-level instructions, with two scan-critical
    refinements: an operand consumed only by a ``dynamic-slice`` inside
    the fusion counts the *slice* bytes (a layer reads its own weight
    slice, not the whole stacked array), and a fusion rooted at
    ``dynamic-update-slice`` counts the *update* bytes (in-place write);
    tuple plumbing (while/get-tuple-element/tuple/bitcast/parameter)
    counts zero;
  * **collective wire bytes** — per-device bytes-on-wire per collective
    (ring model, see ``roofline.py``), also loop-multiplied.

Trip counts: the largest integer constant in the while condition
computation (the canonical `lt(counter, L)` pattern XLA emits for scans).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_INSTR_RE = re.compile(
    r"^(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],{}/* ]+?)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

#: pure data-plumbing opcodes: zero modeled HBM traffic
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "iota", "rng",
    "get-dimension-size", "partition-id", "replica-id", "domain",
    "opt-barrier", "add-dependency", "custom-call",
}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    out_bytes: int
    out_elems: int
    raw: str = ""


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)
    params: Dict[int, Instr] = field(default_factory=dict)
    root: Optional[Instr] = None


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)
    dot_flops_by_shape: Dict[str, float] = field(default_factory=dict)
    max_trip: int = 1
    bytes_by_instr: Dict[str, float] = field(default_factory=dict)

    def top_bytes(self, n: int = 20):
        return sorted(self.bytes_by_instr.items(), key=lambda kv: -kv[1])[:n]

    def add_collective(self, kind: str, b: float, n: int = 1) -> None:
        self.wire_bytes += b
        self.collective_bytes[kind] = \
            self.collective_bytes.get(kind, 0.0) + b
        self.collective_count[kind] = \
            self.collective_count.get(kind, 0) + n


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        hm = _HEADER_RE.match(line)
        if hm and ("=" not in line.split("(")[0]):
            cur = Computation(hm.group(2))
            comps[cur.name] = cur
            if hm.group(1):                      # ENTRY
                comps["__entry__"] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        is_root, name, type_str, opcode, opnds, attrs = im.groups()
        elems, byts = _shape_elems_bytes(type_str)
        operands = []
        depth = 0
        tok = ""
        for ch in opnds:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            if ch == "," and depth == 0:
                operands.append(tok.strip())
                tok = ""
            else:
                tok += ch
        if tok.strip():
            operands.append(tok.strip())
        # operand tokens are "%name" in older XLA dumps and
        # "f32[4,64]{1,0} %name" (inline types) in newer ones
        named = []
        for o in operands:
            om = re.search(r"%([\w.\-]+)", o)
            if om:
                named.append(om.group(1))
        operands = named
        inst = Instr(name, type_str, opcode, operands, attrs, byts, elems,
                     raw=line)
        cur.instrs.append(inst)
        cur.by_name[name] = inst
        if opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", line)
            if pm:
                cur.params[int(pm.group(1))] = inst
        if is_root:
            cur.root = inst
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Largest integer constant reachable from the while condition —
    XLA's canonical `lt(counter, L)` scan pattern."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    seen = set()

    def scan(c: Computation) -> None:
        if c.name in seen:
            return
        seen.add(c.name)
        for inst in c.instrs:
            for m in _CONST_INT_RE.finditer(inst.raw):
                best_holder[0] = max(best_holder[0], int(m.group(1)))
            cm = _CALLS_RE.search(inst.attrs)
            if cm and cm.group(1) in comps:
                scan(comps[cm.group(1)])

    best_holder = [best]
    scan(comp)
    return best_holder[0]


def _dot_flops(comp: Computation, inst: Instr) -> float:
    lhs = comp.by_name.get(inst.operands[0]) if inst.operands else None
    if lhs is None:
        return 2.0 * inst.out_elems          # conservative
    lm = _SHAPE_RE.search(lhs.type_str)
    if not lm:
        return 0.0
    dims = [int(d) for d in lm.group(2).split(",")] if lm.group(2) else []
    cm = _LHS_C_RE.search(inst.attrs)
    contract = 1
    if cm and cm.group(1):
        for i in cm.group(1).split(","):
            idx = int(i)
            if idx < len(dims):
                contract *= dims[idx]
    return 2.0 * inst.out_elems * contract


def _conv_flops(comp: Computation, inst: Instr) -> float:
    # flops = 2 * out_elems * (kernel spatial * in_features)
    rhs = comp.by_name.get(inst.operands[1]) \
        if len(inst.operands) > 1 else None
    if rhs is None:
        return 2.0 * inst.out_elems
    rm = _SHAPE_RE.search(rhs.type_str)
    dims = [int(d) for d in rm.group(2).split(",")] if rm and rm.group(2) \
        else []
    k = 1
    for d in dims[:-1]:
        k *= d
    return 2.0 * inst.out_elems * k


def _collective_wire(inst: Instr) -> Tuple[str, float]:
    kind = next((c for c in COLLECTIVES
                 if inst.opcode == c or inst.opcode.startswith(c)), "")
    if not kind or inst.opcode.endswith("-done"):
        return "", 0.0
    n = 1
    g = _GROUPS_RE.search(inst.attrs)
    if g:
        n = len([x for x in g.group(1).split(",") if x.strip() != ""])
    else:
        g2 = _GROUPS_IOTA_RE.search(inst.attrs)
        if g2:
            n = int(g2.group(2))
    out_b = inst.out_bytes
    if kind == "all-gather":
        wire = out_b * (n - 1) / max(n, 1)
    elif kind == "reduce-scatter":
        wire = out_b * (n - 1)
    elif kind == "all-reduce":
        wire = 2 * out_b * (n - 1) / max(n, 1)
    elif kind == "all-to-all":
        wire = out_b * (n - 1) / max(n, 1)
    else:
        wire = out_b
    return kind, wire


def _fusion_bytes(comps: Dict[str, Computation], comp: Computation,
                  inst: Instr) -> float:
    """Bytes for a fusion op: slice-aware operands + DUS-aware output."""
    called = None
    cm = _CALLS_RE.search(inst.attrs)
    if cm:
        called = comps.get(cm.group(1))
    total = 0.0
    # output: if root is dynamic-update-slice, count the update size
    out_b = inst.out_bytes
    dus_target: Optional[str] = None
    if called is not None and called.root is not None \
            and called.root.opcode == "dynamic-update-slice":
        upd = None
        for opn in called.root.operands[1:2]:
            upd = called.by_name.get(opn)
        if upd is not None:
            out_b = upd.out_bytes
        if called.root.operands:
            dus_target = called.root.operands[0]
    total += out_b
    # operands
    for k, opn in enumerate(inst.operands):
        op_inst = comp.by_name.get(opn)
        op_b = op_inst.out_bytes if op_inst else 0
        if called is not None and k in called.params:
            p = called.params[k]
            users = [i for i in called.instrs if p.name in i.operands]
            if dus_target is not None and users and \
                    all(u.name == called.root.name for u in users) and \
                    p.name == dus_target:
                op_b = 0          # in-place DUS target: no real read
            elif users and all(u.opcode in ("dynamic-slice", "bitcast",
                                            "reshape", "copy")
                               for u in users):
                sl = [u for u in users if u.opcode == "dynamic-slice"]
                if sl:
                    op_b = max(u.out_bytes for u in sl)
        total += op_b
    return total


def _analyze(comps: Dict[str, Computation], comp: Computation,
             mult: float, cost: HloCost, flops_only: bool = False
             ) -> None:
    for inst in comp.instrs:
        op = inst.opcode
        if op == "while":
            bm = _BODY_RE.search(inst.attrs)
            cm = _COND_RE.search(inst.attrs)
            trip = _trip_count(comps, cm.group(1)) if cm else 1
            cost.max_trip = max(cost.max_trip, int(trip * mult))
            if bm and bm.group(1) in comps:
                _analyze(comps, comps[bm.group(1)], mult * trip, cost,
                         flops_only)
            continue
        if op in ("call", "conditional", "async-start"):
            for m in re.finditer(r"(?:to_apply|called_computations=\{?|"
                                 r"branch_computations=\{)%?([\w.\-]+)",
                                 inst.attrs):
                sub = comps.get(m.group(1))
                if sub:
                    _analyze(comps, sub, mult, cost, flops_only)
            continue
        if op == "dot":
            f = _dot_flops(comp, inst) * mult
            cost.flops += f
            key = inst.type_str.strip()
            cost.dot_flops_by_shape[key] = \
                cost.dot_flops_by_shape.get(key, 0.0) + f
        elif op.startswith("convolution"):
            cost.flops += _conv_flops(comp, inst) * mult
        kind, wire = _collective_wire(inst)
        if kind:
            cost.add_collective(kind, wire * mult, int(mult))
            if not flops_only:
                cost.bytes += inst.out_bytes * mult
            continue
        if flops_only:
            # still recurse into fusions for their dots
            if op == "fusion":
                cm2 = _CALLS_RE.search(inst.attrs)
                if cm2 and cm2.group(1) in comps:
                    _analyze(comps, comps[cm2.group(1)], mult, cost,
                             flops_only=True)
            continue
        if op in _FREE_OPS:
            continue
        if op == "fusion":
            fb = _fusion_bytes(comps, comp, inst) * mult
            cost.bytes += fb
            key = f"{comp.name}/{inst.name}"
            cost.bytes_by_instr[key] = cost.bytes_by_instr.get(key, 0.0) + fb
            cm2 = _CALLS_RE.search(inst.attrs)
            if cm2 and cm2.group(1) in comps:
                _analyze(comps, comps[cm2.group(1)], mult, cost,
                         flops_only=True)
            continue
        # plain top-level op: operands + output
        b = inst.out_bytes
        for opn in inst.operands:
            oi = comp.by_name.get(opn)
            if oi is not None:
                b += oi.out_bytes
        cost.bytes += b * mult
        key = f"{comp.name}/{inst.name}({op})"
        cost.bytes_by_instr[key] = cost.bytes_by_instr.get(key, 0.0) \
            + b * mult


def analyze_hlo(text: str) -> HloCost:
    comps = parse_module(text)
    entry = comps.get("__entry__")
    cost = HloCost()
    if entry is None:
        return cost
    _analyze(comps, entry, 1.0, cost)
    return cost
