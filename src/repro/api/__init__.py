"""``repro.api`` — the public deployment surface of the NPU compiler.

The paper's compiler is a product: a workload goes in once, a
CP-optimized program comes out, and that program is what ships (paper
§III).  This package is that product shape:

    import repro.api as api

    model = api.compile("mobilenet_v2", precision="int8")  # PTQ inside
    out = model(image)                          # callable, batched OK
    model.save("mnv2_int8.rpa")                 # versioned artifact
    model = api.CompiledModel.load("mnv2_int8.rpa")   # no recompile

    sess = api.Session(cache_dir=".cache/programs")   # serving fleet
    sess.add("mobilenet_v2", precision="int8")
    sess.add("yolov8n_det")
    sess.run("mobilenet_v2", image)

``compile`` accepts a benchmark model name, a ``Graph`` (+ weights), a
``(Graph, GraphBuilder)`` pair as returned by the frontends, or a
``QuantizedModel`` — and resolves precision, options and execution
semantics so callers never hand-wire graph -> PTQ -> compile -> execute
again.  The older free functions (``repro.core.compile_graph``,
``repro.frontends.vision.build_quantized`` …) remain importable and are
what this surface composes.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Optional, Tuple, Union

from repro.core.ir import Graph, GraphBuilder, graph_precision
from repro.core.npu import NEUTRON_2TOPS, NPUConfig
from repro.core.pipeline import CompilerOptions, compile_graph
from repro.core.serialize import ArtifactError

from repro.runtime.serving import (Cancelled, CircuitBreaker,
                                   DeadlineExceeded, FlushError,
                                   FrameCorrupt, Overloaded, ServingError,
                                   Ticket, WorkerLost)

from .compiled import CompiledModel, resolve_semantics
from .decode import DecodeSession
from .session import Session

from repro.runtime.fleet import Fleet, FleetError, UpdateRejected

__all__ = [
    "compile", "CompiledModel", "Session", "DecodeSession",
    "ArtifactError", "CompilerOptions", "resolve_semantics",
    # serving robustness surface
    "ServingError", "Overloaded", "DeadlineExceeded", "FlushError",
    "WorkerLost", "Ticket", "CircuitBreaker", "Cancelled",
    "FrameCorrupt",
    # fleet-level serving
    "Fleet", "FleetError", "UpdateRejected",
]

Source = Union[str, Graph, GraphBuilder, Tuple[Graph, GraphBuilder],
               "QuantizedModel"]  # noqa: F821


def _is_quantized_model(obj) -> bool:
    from repro.quant import QuantizedModel
    return isinstance(obj, QuantizedModel)


def compile(graph_or_model: Source,                  # noqa: A001
            config: Optional[NPUConfig] = None,
            options: Optional[CompilerOptions] = None, *,
            weights=None,
            precision: str = "auto",
            res_scale: float = 1.0,
            calibration=None,
            calib_samples: int = 4,
            calib_method: str = "minmax",
            calib_percentile: float = 99.9,
            weight_dtype: str = "int8",
            seed: int = 0,
            cache: bool = True,
            name: Optional[str] = None) -> CompiledModel:
    """Compile one workload into a :class:`CompiledModel`.

    ``graph_or_model`` may be a benchmark model name
    (:data:`repro.frontends.vision.VISION_MODELS`), a built ``Graph``
    (pass ``weights`` to make the result executable), a
    ``(Graph, GraphBuilder)`` pair, a ``GraphBuilder``, or a
    ``QuantizedModel``.

    ``precision``:
      * ``"auto"``    — compile whatever the graph is annotated with;
      * ``"float32"`` — assert the graph is float32;
      * ``"int8"``    — run the full PTQ calibration flow internally
        (synthetic calibration set, min-max/percentile observers,
        per-channel int8/int4 weights) when the graph is still float32,
        then compile the quantized graph.  Callers never import
        :mod:`repro.quant` primitives for the standard path.

    ``calibration`` optionally supplies an existing
    ``quant.CalibrationTable`` (keyed by tensor name) so a re-quantize
    of the same model — e.g. an int4-weight variant — skips the float
    reference sweep; the table a compile derived is exposed as
    ``CompiledModel.calibration``.
    """
    if precision not in ("auto", "float32", "int8"):
        raise ValueError(f"precision must be auto/float32/int8, "
                         f"got {precision!r}")
    cfg = config or NEUTRON_2TOPS
    from repro import quant

    qm = None
    g = None
    if isinstance(graph_or_model, str):
        from repro.frontends import vision
        model_name = graph_or_model
        g, b = vision.build(model_name, res_scale=res_scale)
        weights = dict(b._weights)
        name = name or model_name
    elif _is_quantized_model(graph_or_model):
        qm = graph_or_model
        g = qm.graph
        weights = qm.weights_f
    elif isinstance(graph_or_model, tuple):
        g, b = graph_or_model
        weights = weights if weights is not None else dict(b._weights)
    elif isinstance(graph_or_model, GraphBuilder):
        b = graph_or_model
        g = b.g
        weights = weights if weights is not None else dict(b._weights)
    elif isinstance(graph_or_model, Graph):
        g = graph_or_model
        weights = dict(weights) if weights is not None else {}
    else:
        raise TypeError(
            f"cannot compile {type(graph_or_model).__name__}: expected a "
            f"model name, Graph, (Graph, GraphBuilder), GraphBuilder or "
            f"QuantizedModel")

    # PTQ-on-demand: int8 requested for a float graph -> calibrate inside
    calib_table = calibration
    if precision == "int8" and qm is None and \
            graph_precision(g) == "float32":
        if not weights:
            raise ValueError(
                f"precision='int8' on graph {g.name!r} needs weights to "
                f"run PTQ calibration")
        cal = quant.synthetic_calibration(g, samples=calib_samples,
                                          seed=seed)
        if calib_table is None:
            calib_table = quant.calibrate(g, weights, cal,
                                          method=calib_method,
                                          percentile=calib_percentile)
        qm = quant.quantize_graph(g, weights, calib_table,
                                  weight_dtype=weight_dtype)
        quant.measure_quant_error(qm, cal)

    opts = options or CompilerOptions()
    if precision != "auto" and opts.precision == "auto":
        opts = replace(opts, precision=precision)

    result = compile_graph(g, cfg, opts, cache=cache)
    sem = resolve_semantics(g, qm)
    src = "cache" if result.cache_hit else "compile"
    return CompiledModel(name or g.name, g, cfg, opts, result,
                         weights, semantics=sem, qm=qm, source=src,
                         calibration=calib_table)


def load(path: str, **kw) -> CompiledModel:
    """Load a saved artifact (alias for :meth:`CompiledModel.load`)."""
    return CompiledModel.load(path, **kw)
