"""Versioned on-disk artifact format for :class:`repro.api.CompiledModel`.

A deployment artifact is everything a serving process needs to run a
compiled workload *without recompiling*: the annotated graph (dtypes +
qparams), the timed NPU program, the tiling and bank allocation, the
execution weights (float originals plus the integer weight bundle for
quantized programs) and the resolved execution-semantics metadata.

The container is the checksummed zip of :mod:`repro.core.serialize`;
this module adds the model-level payloads and the **staleness contract**:
an artifact records the ``(Graph.fingerprint, NPUConfig,
CompilerOptions)`` key it was compiled under, and loading re-derives the
fingerprint from the embedded graph and re-validates every expectation
the caller supplies — a stale or mismatched artifact raises
:class:`~repro.core.serialize.ArtifactError`, it is never silently
replayed.
"""
from __future__ import annotations

from dataclasses import fields
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core import serialize
from repro.core.ir import Graph
from repro.core.npu import NPUConfig
from repro.core.pipeline import CompileResult, CompilerOptions
from repro.core.serialize import ArtifactError

#: file extension for CompiledModel artifacts ("repro program artifact").
ARTIFACT_SUFFIX = ".rpa"


def options_to_payload(opts: CompilerOptions) -> dict:
    d = {f.name: getattr(opts, f.name) for f in fields(opts)}
    d["formats"] = list(d["formats"])
    return d


def options_from_payload(p: dict) -> CompilerOptions:
    kw = dict(p)
    kw["formats"] = tuple(kw["formats"])
    return CompilerOptions(**kw)


def save_model(path: str, *, name: str, graph: Graph, cfg: NPUConfig,
               options: CompilerOptions, result: CompileResult,
               weights: Dict[str, np.ndarray], precision: str,
               quant_meta: Optional[dict] = None,
               qweights: Optional[Dict[str, np.ndarray]] = None,
               packed: Optional[Dict[str, np.ndarray]] = None,
               calib_error: Optional[Dict[str, float]] = None,
               plan_consts: Optional[Dict[str, np.ndarray]] = None
               ) -> None:
    graph_payload, arrays = serialize.graph_to_payload(graph)
    for wname, arr in weights.items():
        arrays[f"wf/{wname}"] = np.asarray(arr)
    for wname, arr in (qweights or {}).items():
        arrays[f"qw/{wname}"] = np.asarray(arr)
    for wname, arr in (packed or {}).items():
        arrays[f"pk/{wname}"] = np.asarray(arr)
    # lowered-plan kernel constants (version 3): stored under indexed
    # member names (const keys hold step labels with ':'/'@'/'[') with
    # the key order in a payload, so loaders rebuild the exact store
    pl_keys = sorted(plan_consts or ())
    for i, ckey in enumerate(pl_keys):
        arrays[f"pl/{i:04d}"] = np.asarray(plan_consts[ckey])
    key = {
        "kind": "compiled-model",
        "fingerprint": graph.fingerprint(),
        "cfg": serialize.config_to_payload(cfg),
        "opts": serialize.options_digest(options.cache_key()),
        "precision": precision,
        "name": name,
    }
    payloads = {
        "model": {
            "name": name,
            "precision": precision,
            "options": options_to_payload(options),
            "quant": quant_meta,
            "calib_error": calib_error or {},
        },
        "graph": graph_payload,
        "program": serialize.program_to_payload(result.program),
        "plan": serialize.plan_to_payload(result.plan),
        "tiling": serialize.tiling_to_payload(result.tiling),
        "allocation": serialize.allocation_to_payload(result.allocation),
    }
    if pl_keys:
        payloads["planconsts"] = {"keys": pl_keys}
    serialize.write_artifact(path, key, payloads, arrays)


def load_model(path: str, *,
               expect_graph: Optional[Graph] = None,
               expect_cfg: Optional[NPUConfig] = None,
               expect_options: Optional[CompilerOptions] = None,
               mmap: bool = False
               ) -> Tuple[dict, Graph, NPUConfig, CompilerOptions,
                          CompileResult, Dict[str, np.ndarray],
                          Dict[str, np.ndarray], Dict[str, np.ndarray],
                          Optional[Dict[str, np.ndarray]]]:
    """Load + validate a CompiledModel artifact.

    Returns ``(model_payload, graph, cfg, options, result, weights,
    qweights, packed, plan_consts)`` — ``plan_consts`` maps lowering
    const keys to their persisted arrays (version-3 artifacts), or None
    when the artifact predates them.  Validation: container integrity
    (checksums,
    version) via :func:`repro.core.serialize.read_artifact`, then the
    embedded graph's *recomputed* fingerprint must equal the stored key
    (catches hand-edits and fingerprint-algorithm drift), then any
    ``expect_*`` the caller passes must match the key (catches serving a
    program compiled for a different model/config/options).

    ``mmap=True`` maps weight arrays copy-on-write out of the (stored,
    version-2) artifact instead of materializing them in RAM; the
    sha256 manifest is still fully validated either way.
    """
    key, payloads, arrays = serialize.read_artifact(path,
                                                    mmap_arrays=mmap)
    if key.get("kind") != "compiled-model":
        raise ArtifactError(
            f"{path}: artifact kind {key.get('kind')!r} is not a "
            f"compiled model")
    graph = serialize.graph_from_payload(payloads["graph"], arrays)
    fp = graph.fingerprint()
    if fp != key.get("fingerprint"):
        raise ArtifactError(
            f"{path}: stale artifact — embedded graph fingerprint "
            f"{fp[:12]}… does not match stored key "
            f"{str(key.get('fingerprint'))[:12]}…")
    cfg = serialize.config_from_payload(key["cfg"])
    options = options_from_payload(payloads["model"]["options"])
    if serialize.options_digest(options.cache_key()) != key.get("opts"):
        raise ArtifactError(
            f"{path}: stale artifact — stored options do not match key")
    if expect_graph is not None and expect_graph.fingerprint() != fp:
        raise ArtifactError(
            f"{path}: artifact was compiled for a different graph "
            f"(stale for {expect_graph.name!r})")
    if expect_cfg is not None and expect_cfg != cfg:
        raise ArtifactError(
            f"{path}: artifact was compiled for config "
            f"{cfg.name!r}, not {expect_cfg.name!r}")
    if expect_options is not None and \
            expect_options.cache_key() != options.cache_key():
        raise ArtifactError(
            f"{path}: artifact was compiled under different options")
    result = CompileResult(
        serialize.program_from_payload(payloads["program"]),
        serialize.plan_from_payload(payloads["plan"]),
        serialize.tiling_from_payload(payloads["tiling"]),
        serialize.allocation_from_payload(payloads["allocation"]),
        compile_s=0.0, phase_s={}, cache_hit=True, cache_key=fp,
        cache_tier="artifact")
    weights = {k[3:]: arrays[k] for k in arrays if k.startswith("wf/")}
    qweights = {k[3:]: arrays[k] for k in arrays if k.startswith("qw/")}
    packed = {k[3:]: arrays[k] for k in arrays if k.startswith("pk/")}
    plan_consts = None
    pc = payloads.get("planconsts")
    if pc is not None:
        try:
            plan_consts = {ckey: arrays[f"pl/{i:04d}"]
                           for i, ckey in enumerate(pc["keys"])}
        except KeyError as e:
            raise ArtifactError(
                f"{path}: planconsts key index references missing "
                f"array member ({e})") from None
    return (payloads["model"], graph, cfg, options, result,
            weights, qweights, packed, plan_consts)
