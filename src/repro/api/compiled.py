"""The deployable unit of the ``repro.api`` surface.

A :class:`CompiledModel` is what the paper ships to devices: one
workload, compiled once, bundled with everything needed to execute it —
the timed :class:`~repro.core.program.NPUProgram`, the tiling, the bank
allocation, the (integer or float) weights, and the resolved execution
semantics.  It is directly callable on single or batched inputs,
reports its own statistics, and round-trips through the versioned
on-disk artifact format of :mod:`repro.api.artifact`:

    model = repro.api.compile("mobilenet_v2", precision="int8")
    logits = model(image)                   # single (H, W, C) input
    batch = model(images)                   # (B, H, W, C) batch
    model.save("mnv2.rpa")
    model = CompiledModel.load("mnv2.rpa")  # no recompilation
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Union

import numpy as np

from repro.core.executor import (ExecSemantics, ExecutionReport,
                                 FLOAT_SEMANTICS, execute)
from repro.core.ir import Graph, graph_precision
from repro.core.npu import NPUConfig
from repro.core.pipeline import CompileResult, CompilerOptions

from . import artifact as _artifact

Inputs = Union[np.ndarray, Dict[str, np.ndarray]]


def resolve_semantics(graph: Graph, qm=None,
                      sem_meta: Optional[dict] = None
                      ) -> Optional[ExecSemantics]:
    """Execution semantics implied by a graph's precision annotation
    (plus, for quantized graphs, the integer-weight bundle and any
    persisted semantics metadata).  A dtype-cast graph with no qparams
    anywhere (``repro.quant.cast_graph`` — the cost-model-only
    annotation) has *no* executable semantics and resolves to None."""
    if graph_precision(graph) == "float32":
        return FLOAT_SEMANTICS
    if qm is None:
        if not any(t.qparams is not None for t in graph.tensors.values()):
            return None               # cast-only: latency model, no replay
        raise ValueError(
            f"graph {graph.name!r} is quantized but no QuantizedModel "
            f"bundle was provided")
    from repro.quant import QuantSemantics
    if sem_meta:
        return QuantSemantics.from_meta(qm, sem_meta)
    return QuantSemantics(qm)


@dataclass
class CompiledModel:
    """A compiled, executable, persistable NPU workload."""

    name: str
    graph: Graph
    cfg: NPUConfig
    options: CompilerOptions
    result: CompileResult
    weights: Dict[str, np.ndarray]           # float execution weights
    semantics: ExecSemantics = field(default=FLOAT_SEMANTICS, repr=False)
    qm: Optional[object] = field(default=None, repr=False)  # QuantizedModel
    source: str = "compile"                  # "compile" | "cache" | path
    #: the quant.CalibrationTable a PTQ-inside compile derived (reusable
    #: via api.compile(..., calibration=...); not persisted in artifacts)
    calibration: Optional[dict] = field(default=None, repr=False)

    # -- structure ----------------------------------------------------------
    @property
    def program(self):
        return self.result.program

    @property
    def tiling(self):
        return self.result.tiling

    @property
    def allocation(self):
        return self.result.allocation

    @property
    def plan(self):
        return self.result.plan

    @property
    def precision(self) -> str:
        if self.semantics is None:    # dtype-cast, cost-model-only
            return graph_precision(self.graph)
        return self.semantics.name

    @property
    def fingerprint(self) -> str:
        return self.result.cache_key or self.graph.fingerprint()

    @property
    def compile_s(self) -> float:
        return self.result.compile_s

    @property
    def cache_tier(self) -> Optional[str]:
        return self.result.cache_tier

    # -- execution ----------------------------------------------------------
    def _normalize(self, inputs: Inputs) -> Dict[str, np.ndarray]:
        if isinstance(inputs, np.ndarray):
            ins = self.graph.inputs
            if len(ins) != 1:
                raise ValueError(
                    f"{self.name}: graph has {len(ins)} inputs — pass a "
                    f"dict of name -> array")
            return {ins[0].name: inputs}
        return dict(inputs)

    def _batch_size(self, feed: Dict[str, np.ndarray]) -> Optional[int]:
        sizes = set()
        for t in self.graph.inputs:
            arr = np.asarray(feed[t.name])
            if arr.ndim == len(t.shape) + 1 and arr.shape[1:] == t.shape:
                sizes.add(arr.shape[0])
            elif arr.shape != t.shape:
                raise ValueError(
                    f"{self.name}: input {t.name} has shape {arr.shape}, "
                    f"expected {t.shape} or (B, *{t.shape})")
        if len(sizes) > 1:
            raise ValueError(f"{self.name}: inconsistent batch sizes "
                             f"{sorted(sizes)}")
        return sizes.pop() if sizes else None

    def _run_one(self, feed: Dict[str, np.ndarray],
                 check: bool) -> Dict[str, np.ndarray]:
        if self.semantics is None:
            raise RuntimeError(
                f"{self.name}: compiled from a dtype-cast graph "
                f"(cost-model-only) — no executable semantics")
        rep = execute(self.program, self.graph, self.tiling, feed,
                      self.weights, check=check,
                      semantics=self.semantics)
        if check:
            return rep.outputs       # already decoded + oracle-verified
        return {name: self.semantics.decode(name, arr)
                for name, arr in rep.outputs.items()}

    def __call__(self, inputs: Inputs,
                 check: bool = False) -> Dict[str, np.ndarray]:
        """Run the compiled program.  ``inputs`` is one array (single-
        input graphs), a dict of name -> array, or either with a leading
        batch axis — batched calls run the batch-1 program per sample
        (edge inference is batch-1 by construction, paper §IV) and stack
        the outputs.  ``check=True`` additionally verifies every output
        against the functional oracle."""
        feed = self._normalize(inputs)
        batch = self._batch_size(feed)
        if batch is None:
            return self._run_one(feed, check)
        outs: Dict[str, list] = {}
        for i in range(batch):
            sample = {}
            for t in self.graph.inputs:
                arr = np.asarray(feed[t.name])
                sample[t.name] = arr[i] if arr.ndim == len(t.shape) + 1 \
                    else arr
            res = self._run_one(sample, check)
            for name, val in res.items():
                outs.setdefault(name, []).append(val)
        return {name: np.stack(vals) for name, vals in outs.items()}

    def verify(self, inputs: Inputs) -> ExecutionReport:
        """Checked single-sample replay vs the functional oracle."""
        feed = self._normalize(inputs)
        if self._batch_size(feed) is not None:
            raise ValueError("verify() takes a single (unbatched) sample")
        return execute(self.program, self.graph, self.tiling, feed,
                       self.weights, check=True, semantics=self.semantics)

    # -- reporting ----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        s = self.result.stats()
        s["precision"] = self.precision
        s["fingerprint"] = self.fingerprint
        return s

    def report(self) -> str:
        s = self.program.stats()
        ts = self.tiling.stats or {}
        fused = ts.get("fused_steps", 0)
        cov = f"{100.0 * ts.get('fused_steps_cp', 0) / fused:.0f}%" \
            if fused else "n/a (no fused regions)"
        lines = [
            f"CompiledModel {self.name!r}  [{self.precision}]",
            f"  config       {self.cfg.name}  "
            f"({self.cfg.peak_tops:.1f} peak TOPS, "
            f"{self.cfg.tcm_bytes // 1024} KiB TCM / "
            f"{self.cfg.tcm_banks} banks)",
            f"  fingerprint  {self.fingerprint[:16]}…",
            f"  source       {self.source}"
            + (f" (cache tier: {self.cache_tier})" if self.cache_tier
               else ""),
            f"  compile      {self.result.compile_s * 1e3:.1f} ms",
            f"  program      {s['ticks']:.0f} ticks, "
            f"{s['gmacs']:.2f} GMACs, {s['ddr_mb']:.2f} MB DDR",
            # fusion coverage: how much of the fusion-eligible work the
            # CP actually optimized (the rest ran the greedy order)
            f"  fusion       {ts.get('cp_regions', 0)} CP + "
            f"{ts.get('windowed_regions', 0)} windowed "
            f"({ts.get('windows', 0)} windows) + "
            f"{ts.get('greedy_regions', 0)} greedy regions, "
            f"{ts.get('layerwise_regions', 0)} layer-wise; "
            f"optimized fused steps: {cov}",
            f"  latency      {s['latency_ms']:.3f} ms modeled "
            f"({s['effective_tops']:.2f} effective TOPS, "
            f"{100 * s['utilization']:.0f}% of peak)",
        ]
        return "\n".join(lines)

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> str:
        """Write the versioned on-disk artifact (everything needed to
        :meth:`load` and execute in another process, no recompile)."""
        if self.semantics is None:
            raise RuntimeError(
                f"{self.name}: cost-model-only models (dtype-cast "
                f"graphs) are not persistable deployment artifacts")
        quant_meta = None
        qweights = packed = None
        calib_error = None
        if self.qm is not None:
            quant_meta = self.semantics.meta() \
                if hasattr(self.semantics, "meta") else None
            qweights = self.qm.qweights
            packed = self.qm.packed
            calib_error = self.qm.calib_error
        _artifact.save_model(
            path, name=self.name, graph=self.graph, cfg=self.cfg,
            options=self.options, result=self.result,
            weights=self.weights, precision=self.precision,
            quant_meta=quant_meta, qweights=qweights, packed=packed,
            calib_error=calib_error)
        return path

    @classmethod
    def load(cls, path: str, *,
             expect_graph: Optional[Graph] = None,
             expect_cfg: Optional[NPUConfig] = None,
             expect_options: Optional[CompilerOptions] = None
             ) -> "CompiledModel":
        """Load an artifact written by :meth:`save`.  Integrity and
        staleness are validated (see :mod:`repro.api.artifact`); a bad
        artifact raises :class:`repro.core.serialize.ArtifactError`."""
        (model_p, graph, cfg, options, result, weights, qweights,
         packed) = _artifact.load_model(
            path, expect_graph=expect_graph, expect_cfg=expect_cfg,
            expect_options=expect_options)
        qm = None
        sem_meta = model_p.get("quant")
        if model_p["precision"] != "float32":
            from repro.quant import QuantizedModel
            qm = QuantizedModel(
                graph, qweights, packed, weights,
                weight_dtype=(sem_meta or {}).get("weight_dtype", "int8"),
                calib_error={k: float(v) for k, v in
                             (model_p.get("calib_error") or {}).items()})
        sem = resolve_semantics(graph, qm, sem_meta)
        return cls(model_p["name"], graph, cfg, options, result, weights,
                   semantics=sem, qm=qm, source=path)
