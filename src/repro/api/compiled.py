"""The deployable unit of the ``repro.api`` surface.

A :class:`CompiledModel` is what the paper ships to devices: one
workload, compiled once, bundled with everything needed to execute it —
the timed :class:`~repro.core.program.NPUProgram`, the tiling, the bank
allocation, the (integer or float) weights, and the resolved execution
semantics.  It is directly callable on single or batched inputs,
reports its own statistics, and round-trips through the versioned
on-disk artifact format of :mod:`repro.api.artifact`:

    model = repro.api.compile("mobilenet_v2", precision="int8")
    logits = model(image)                   # single (H, W, C) input
    batch = model(images)                   # (B, H, W, C) batch
    model.save("mnv2.rpa")
    model = CompiledModel.load("mnv2.rpa")  # no recompilation
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.execplan import (ExecPlan, PlanConsts, lower_plan,
                                 lower_steps)
from repro.core.executor import (ExecSemantics, ExecutionError,
                                 ExecutionReport, FLOAT_SEMANTICS, execute)
from repro.core.ir import Graph, graph_precision
from repro.core.npu import NPUConfig
from repro.core.pipeline import CompileResult, CompilerOptions

from . import artifact as _artifact

Inputs = Union[np.ndarray, Dict[str, np.ndarray]]

#: batch-size buckets compiled replay plans are built for.  A request
#: batch is served by the smallest bucket that fits it (ragged tails
#: just run the bucket partially full); batches past the largest bucket
#: are chunked.
PLAN_BUCKETS = (1, 2, 4, 8, 16, 32)


def resolve_semantics(graph: Graph, qm=None,
                      sem_meta: Optional[dict] = None
                      ) -> Optional[ExecSemantics]:
    """Execution semantics implied by a graph's precision annotation
    (plus, for quantized graphs, the integer-weight bundle and any
    persisted semantics metadata).  A dtype-cast graph with no qparams
    anywhere (``repro.quant.cast_graph`` — the cost-model-only
    annotation) has *no* executable semantics and resolves to None."""
    if graph_precision(graph) == "float32":
        return FLOAT_SEMANTICS
    if qm is None:
        if not any(t.qparams is not None for t in graph.tensors.values()):
            return None               # cast-only: latency model, no replay
        raise ValueError(
            f"graph {graph.name!r} is quantized but no QuantizedModel "
            f"bundle was provided")
    from repro.quant import QuantSemantics
    if sem_meta:
        return QuantSemantics.from_meta(qm, sem_meta)
    return QuantSemantics(qm)


@dataclass
class CompiledModel:
    """A compiled, executable, persistable NPU workload."""

    name: str
    graph: Graph
    cfg: NPUConfig
    options: CompilerOptions
    result: CompileResult
    weights: Dict[str, np.ndarray]           # float execution weights
    semantics: ExecSemantics = field(default=FLOAT_SEMANTICS, repr=False)
    qm: Optional[object] = field(default=None, repr=False)  # QuantizedModel
    source: str = "compile"                  # "compile" | "cache" | path
    #: the quant.CalibrationTable a PTQ-inside compile derived (reusable
    #: via api.compile(..., calibration=...); not persisted in artifacts)
    calibration: Optional[dict] = field(default=None, repr=False)
    #: lazily built compiled replay plans, keyed by
    #: (graph fingerprint, semantics dtype, batch bucket)
    _plans: Dict[tuple, ExecPlan] = field(default_factory=dict, repr=False)
    #: get-or-compute store for the lowering-time kernel constants;
    #: version-3 artifacts persist it so loaded models serve the derived
    #: arrays (memory-mapped) instead of recomputing them
    _plan_consts: Optional[PlanConsts] = field(default=None, repr=False)
    _plan_stats: Dict[str, float] = field(
        default_factory=lambda: {"builds": 0, "hits": 0, "build_s": 0.0,
                                 "plan_requests": 0, "plan_batches": 0},
        repr=False)

    # -- structure ----------------------------------------------------------
    @property
    def program(self):
        return self.result.program

    @property
    def tiling(self):
        return self.result.tiling

    @property
    def allocation(self):
        return self.result.allocation

    @property
    def plan(self):
        return self.result.plan

    @property
    def precision(self) -> str:
        if self.semantics is None:    # dtype-cast, cost-model-only
            return graph_precision(self.graph)
        return self.semantics.name

    @property
    def fingerprint(self) -> str:
        fp = self.result.cache_key
        if fp is None:
            fp = getattr(self, "_fp_memo", None)
            if fp is None:    # hash once — this sits on the request path
                fp = self._fp_memo = self.graph.fingerprint()
        return fp

    @property
    def compile_s(self) -> float:
        return self.result.compile_s

    @property
    def cache_tier(self) -> Optional[str]:
        return self.result.cache_tier

    # -- execution ----------------------------------------------------------
    def _normalize(self, inputs: Inputs) -> Dict[str, np.ndarray]:
        if isinstance(inputs, np.ndarray):
            ins = self.graph.inputs
            if len(ins) != 1:
                raise ValueError(
                    f"{self.name}: graph has {len(ins)} inputs — pass a "
                    f"dict of name -> array")
            return {ins[0].name: inputs}
        return dict(inputs)

    def _batch_size(self, feed: Dict[str, np.ndarray]) -> Optional[int]:
        sizes = set()
        for t in self.graph.inputs:
            arr = np.asarray(feed[t.name])
            if arr.ndim == len(t.shape) + 1 and arr.shape[1:] == t.shape:
                sizes.add(arr.shape[0])
            elif arr.shape != t.shape:
                raise ValueError(
                    f"{self.name}: input {t.name} has shape {arr.shape}, "
                    f"expected {t.shape} or (B, *{t.shape})")
        if len(sizes) > 1:
            raise ValueError(f"{self.name}: inconsistent batch sizes "
                             f"{sorted(sizes)}")
        return sizes.pop() if sizes else None

    def _require_semantics(self) -> None:
        if self.semantics is None:
            raise RuntimeError(
                f"{self.name}: compiled from a dtype-cast graph "
                f"(cost-model-only) — no executable semantics")

    def _run_one(self, feed: Dict[str, np.ndarray],
                 check: bool) -> Dict[str, np.ndarray]:
        self._require_semantics()
        rep = execute(self.program, self.graph, self.tiling, feed,
                      self.weights, check=check,
                      semantics=self.semantics)
        if check:
            return rep.outputs       # already decoded + oracle-verified
        return {name: self.semantics.decode(name, arr)
                for name, arr in rep.outputs.items()}

    # -- compiled replay plans ---------------------------------------------
    def plan_for(self, batch: int = 1, owner=None) -> ExecPlan:
        """The compiled replay plan serving a ``batch``-request group:
        lowered lazily, cached per batch-size bucket (and per execution
        dtype — an int8 model's plans never alias a float32 model's,
        the graph fingerprint is part of the key).  Step lowering —
        with its pre-gathered, pre-cast weight constants — runs once
        per model and is shared across buckets; only the arena is
        per-bucket.

        ``owner`` keys an additional arena dimension: a plan's arena is
        single-threaded state, so each serving-pool worker passes its
        worker id to get its *own* arena while still sharing the
        one-time step lowering with every other worker."""
        self._require_semantics()
        bucket = next((b for b in PLAN_BUCKETS if b >= batch),
                      PLAN_BUCKETS[-1])
        key = (self.fingerprint, self.semantics.name, bucket, owner)
        plan = self._plans.get(key)
        if plan is None:
            lowered = getattr(self, "_lowered_steps", None)
            if lowered is None:
                t0 = _time.monotonic()
                if self._plan_consts is None:
                    self._plan_consts = PlanConsts()
                lowered = lower_steps(self.program, self.graph,
                                      self.tiling, self.weights,
                                      self.semantics,
                                      consts=self._plan_consts)
                self._lowered_steps = lowered
                self._plan_stats["build_s"] += _time.monotonic() - t0
            plan = lower_plan(self.program, self.graph, self.tiling,
                              self.weights, self.semantics,
                              capacity=bucket, lowered=lowered)
            self._plans[key] = plan
            self._plan_stats["builds"] += 1
            self._plan_stats["build_s"] += plan.build_s
        else:
            self._plan_stats["hits"] += 1
        return plan

    def plan_cache_info(self) -> Dict[str, object]:
        info = dict(self._plan_stats)
        info["plans"] = sorted(
            (fp[:12], sem, bucket, "-" if owner is None else str(owner))
            for fp, sem, bucket, owner in self._plans)
        pc = self._plan_consts
        info["consts"] = len(pc) if pc is not None else 0
        info["consts_computed"] = pc.computed if pc is not None else 0
        info["consts_served"] = pc.served if pc is not None else 0
        return info

    def invalidate_plans(self) -> None:
        """Drop every cached replay plan, the shared lowered step list
        *and* the kernel-constant store, forcing a fresh re-lower from
        the raw weights on the next request.  The serving runtime's
        circuit-breaker recovery path calls this: if a plan (or its
        pre-gathered/persisted constants) went bad, the rebuilt one
        must not share any state with it."""
        self._plans.clear()
        self._lowered_steps = None
        self._plan_consts = PlanConsts()

    def _run_plan_batch(self, stacked: Dict[str, np.ndarray], n: int,
                        owner=None) -> Dict[str, np.ndarray]:
        """Run ``n`` stacked requests through bucketed plans (chunking
        past the largest bucket)."""
        cap = PLAN_BUCKETS[-1]
        self._plan_stats["plan_requests"] += n
        if n <= cap:
            self._plan_stats["plan_batches"] += 1
            return self.plan_for(n, owner=owner).run(stacked, n=n)
        outs: Dict[str, list] = {}
        for i in range(0, n, cap):
            j = min(i + cap, n)
            chunk = {k: v[i:j] for k, v in stacked.items()}
            self._plan_stats["plan_batches"] += 1
            res = self.plan_for(j - i, owner=owner).run(chunk, n=j - i)
            for name, val in res.items():
                outs.setdefault(name, []).append(val)
        return {name: np.concatenate(vals) for name, vals in outs.items()}

    def __call__(self, inputs: Inputs, check: bool = False,
                 engine: Optional[str] = None) -> Dict[str, np.ndarray]:
        """Run the compiled model.  ``inputs`` is one array (single-
        input graphs), a dict of name -> array, or either with a leading
        batch axis.

        Requests are served by the **compiled replay plan** (lowered
        once, batch-vectorized; see :mod:`repro.core.execplan`) — the
        plan's outputs are bit-exact with the interpretive executor for
        float32 and match its stored integers for int8/int4.  Pass
        ``engine="interp"`` to force the interpretive (validating)
        executor; ``check=True`` implies it and additionally verifies
        every output against the functional oracle, per sample."""
        feed = self._normalize(inputs)
        batch = self._batch_size(feed)
        if engine is None:
            engine = "interp" if check else "plan"
        if engine not in ("plan", "interp"):
            raise ValueError(f"engine must be 'plan'/'interp', "
                             f"got {engine!r}")
        if check and engine == "plan":
            raise ValueError(
                "check=True runs the interpretive oracle path — use "
                "verify() to cross-check the plan against it")
        if engine == "plan":
            self._require_semantics()
            stacked = {k: np.asarray(v) for k, v in feed.items()}
            if batch is None:
                return self.plan_for(1).run(stacked)   # unbatched shapes
            return self._run_plan_batch(stacked, batch)
        if batch is None:
            return self._run_one(feed, check)
        outs: Dict[str, list] = {}
        for i in range(batch):
            sample = {}
            for t in self.graph.inputs:
                arr = np.asarray(feed[t.name])
                sample[t.name] = arr[i] if arr.ndim == len(t.shape) + 1 \
                    else arr
            res = self._run_one(sample, check)
            for name, val in res.items():
                outs.setdefault(name, []).append(val)
        return {name: np.stack(vals) for name, vals in outs.items()}

    def run_many(self, requests: List[Inputs], check: bool = False,
                 owner=None) -> List[Dict[str, np.ndarray]]:
        """Execute a group of independent requests as one (or a few)
        batched plan replays; returns one output dict per request in
        order.  ``check=True`` falls back to per-sample interpretive
        oracle replay.  ``owner`` selects a per-caller plan arena (see
        :meth:`plan_for` — serving-pool workers pass their id so
        concurrent batches never share an arena)."""
        if not requests:
            return []
        feeds = [self._normalize(r) for r in requests]
        for f in feeds:
            if self._batch_size(f) is not None:
                raise ValueError(
                    f"{self.name}: run_many takes single-sample requests"
                    f" — pass a batched array to __call__ instead")
        if check:
            return [self._run_one(f, True) for f in feeds]
        self._require_semantics()
        stacked = {t.name: np.stack([f[t.name] for f in feeds])
                   for t in self.graph.inputs}
        res = self._run_plan_batch(stacked, len(feeds), owner=owner)
        return [{name: vals[i] for name, vals in res.items()}
                for i in range(len(feeds))]

    def verify(self, inputs: Inputs) -> ExecutionReport:
        """Checked single-sample replay exercising **both** execution
        paths: the interpretive executor replays against the functional
        oracle (residency/persistency/bank invariants included), then
        the compiled replay plan runs the same sample and its outputs
        are asserted against the interpreter's — bit-exact for float32,
        within one output quantization step for int8/int4."""
        feed = self._normalize(inputs)
        if self._batch_size(feed) is not None:
            raise ValueError("verify() takes a single (unbatched) sample")
        rep = execute(self.program, self.graph, self.tiling, feed,
                      self.weights, check=True, semantics=self.semantics)
        plan_out = self.plan_for(1).run(
            {k: np.asarray(v) for k, v in feed.items()})
        for t in self.graph.outputs:
            got = plan_out[t.name]
            want = rep.outputs[t.name]
            err = float(np.max(np.abs(got - want))) if got.size else 0.0
            tol = self.semantics.plan_parity_tol(t.name)
            if err > tol:
                raise ExecutionError(
                    f"{self.name}: plan replay diverged from the "
                    f"interpretive executor on {t.name}: max|err|="
                    f"{err:.3e} (tol {tol:.3e})")
        return rep

    # -- reporting ----------------------------------------------------------
    def profile(self, inputs: Optional[Inputs] = None, batch: int = 8,
                runs: int = 3):
        """Timed, per-kernel-instrumented replay correlated against the
        cost model: modeled vs measured latency/occupancy/DDR bandwidth
        plus a per-op share-skew table (see
        :func:`repro.obs.profile.profile_model`).  Print the returned
        :class:`~repro.obs.profile.ProfileReport` or ship its
        ``as_dict()``."""
        from repro.obs.profile import profile_model
        return profile_model(self, inputs=inputs, batch=batch, runs=runs)

    def stats(self) -> Dict[str, float]:
        s = self.result.stats()
        s["precision"] = self.precision
        s["fingerprint"] = self.fingerprint
        s["plan"] = self.plan_cache_info()
        return s

    def report(self) -> str:
        s = self.program.stats()
        ts = self.tiling.stats or {}
        fused = ts.get("fused_steps", 0)
        cov = f"{100.0 * ts.get('fused_steps_cp', 0) / fused:.0f}%" \
            if fused else "n/a (no fused regions)"
        lines = [
            f"CompiledModel {self.name!r}  [{self.precision}]",
            f"  config       {self.cfg.name}  "
            f"({self.cfg.peak_tops:.1f} peak TOPS, "
            f"{self.cfg.tcm_bytes // 1024} KiB TCM / "
            f"{self.cfg.tcm_banks} banks)",
            f"  fingerprint  {self.fingerprint[:16]}…",
            f"  source       {self.source}"
            + (f" (cache tier: {self.cache_tier})" if self.cache_tier
               else ""),
            f"  compile      {self.result.compile_s * 1e3:.1f} ms",
            f"  program      {s['ticks']:.0f} ticks, "
            f"{s['gmacs']:.2f} GMACs, {s['ddr_mb']:.2f} MB DDR",
            # fusion coverage: how much of the fusion-eligible work the
            # CP actually optimized (the rest ran the greedy order)
            f"  fusion       {ts.get('cp_regions', 0)} CP + "
            f"{ts.get('windowed_regions', 0)} windowed "
            f"({ts.get('windows', 0)} windows) + "
            f"{ts.get('greedy_regions', 0)} greedy regions, "
            f"{ts.get('layerwise_regions', 0)} layer-wise; "
            f"optimized fused steps: {cov}",
            f"  latency      {s['latency_ms']:.3f} ms modeled "
            f"({s['effective_tops']:.2f} effective TOPS, "
            f"{100 * s['utilization']:.0f}% of peak)",
        ]
        ps = self._plan_stats
        if self._plans:
            buckets = sorted({b for (_, _, b, _) in self._plans})
            kernels = sum(len(p.steps) for p in self._plans.values())
            arena = max(p.arena_bytes for p in self._plans.values())
            lines.append(
                f"  replay       {len(self._plans)} plan(s), buckets "
                f"{buckets}, {kernels} kernels, arena "
                f"{arena / 1024:.0f} KiB/request, built in "
                f"{ps['build_s'] * 1e3:.1f} ms "
                f"({ps['plan_requests']:.0f} plan requests in "
                f"{ps['plan_batches']:.0f} batches)")
        else:
            lines.append("  replay       no plans built yet "
                         "(lowered lazily on first request)")
        return "\n".join(lines)

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> str:
        """Write the versioned on-disk artifact (everything needed to
        :meth:`load` and execute in another process, no recompile —
        including the lowered-plan kernel constants, so a loading
        worker's first request serves them instead of re-deriving)."""
        if self.semantics is None:
            raise RuntimeError(
                f"{self.name}: cost-model-only models (dtype-cast "
                f"graphs) are not persistable deployment artifacts")
        if self._plan_consts is None or not len(self._plan_consts):
            self.plan_for(1)          # populate the constant store
        quant_meta = None
        qweights = packed = None
        calib_error = None
        if self.qm is not None:
            quant_meta = self.semantics.meta() \
                if hasattr(self.semantics, "meta") else None
            qweights = self.qm.qweights
            packed = self.qm.packed
            calib_error = self.qm.calib_error
        _artifact.save_model(
            path, name=self.name, graph=self.graph, cfg=self.cfg,
            options=self.options, result=self.result,
            weights=self.weights, precision=self.precision,
            quant_meta=quant_meta, qweights=qweights, packed=packed,
            calib_error=calib_error,
            plan_consts=self._plan_consts.as_arrays())
        return path

    @classmethod
    def load(cls, path: str, *,
             expect_graph: Optional[Graph] = None,
             expect_cfg: Optional[NPUConfig] = None,
             expect_options: Optional[CompilerOptions] = None,
             mmap: bool = False) -> "CompiledModel":
        """Load an artifact written by :meth:`save`.  Integrity and
        staleness are validated (see :mod:`repro.api.artifact`); a bad
        artifact raises :class:`repro.core.serialize.ArtifactError`.
        ``mmap=True`` maps weights copy-on-write out of the artifact
        (many-model fleets share one page-cache copy per weight)."""
        (model_p, graph, cfg, options, result, weights, qweights,
         packed, plan_consts) = _artifact.load_model(
            path, expect_graph=expect_graph, expect_cfg=expect_cfg,
            expect_options=expect_options, mmap=mmap)
        qm = None
        sem_meta = model_p.get("quant")
        if model_p["precision"] != "float32":
            from repro.quant import QuantizedModel
            qm = QuantizedModel(
                graph, qweights, packed, weights,
                weight_dtype=(sem_meta or {}).get("weight_dtype", "int8"),
                calib_error={k: float(v) for k, v in
                             (model_p.get("calib_error") or {}).items()})
        sem = resolve_semantics(graph, qm, sem_meta)
        return cls(model_p["name"], graph, cfg, options, result, weights,
                   semantics=sem, qm=qm, source=path,
                   _plan_consts=PlanConsts(plan_consts)
                   if plan_consts else None)
