"""Multi-model serving session: micro-batching + admission policy.

A :class:`Session` is the fleet-facing object: a registry of
:class:`~repro.api.compiled.CompiledModel` instances (each with its own
precision) behind one hardware config, one options baseline and one
two-tier (in-process LRU + on-disk artifact) compiled-program cache.
Typical serving flow:

    sess = Session(cache_dir="/var/cache/neutron", max_batch=8)
    sess.add("mobilenet_v2", precision="int8", pin=True)  # hot model
    sess.add("yolov8n_det")                               # float32
    out = sess.run("mobilenet_v2", image)         # single request
    outs = sess.run_many("mobilenet_v2", images)  # one plan replay

    t1 = sess.submit("mobilenet_v2", img_a)       # coalescing queue
    t2 = sess.submit("mobilenet_v2", img_b)
    sess.flush()                                  # one batched replay
    t1.result(), t2.result()

Requests execute on each model's **compiled replay plan** (lowered
once, batch-vectorized — see :mod:`repro.core.execplan`); the
request-coalescing queue groups same-model submissions into one plan
execution of up to ``max_batch`` requests.  ``pin()`` marks a model's
compiled program exempt from the in-process LRU eviction (the
admission policy for hot models); pinned counts are surfaced in
``program_cache_info()`` / :meth:`stats`.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.core.npu import NEUTRON_2TOPS, NPUConfig
from repro.core.pipeline import (CompilerOptions, program_cache_configure,
                                 program_cache_info, program_cache_pin,
                                 program_cache_unpin)

from .compiled import CompiledModel, Inputs


class Ticket:
    """Handle for one queued request.  ``result()`` flushes the owning
    session's queue if the request has not been executed yet, and
    re-raises the execution error if its batch failed."""

    __slots__ = ("_session", "_done", "_value", "_error")

    def __init__(self, session: "Session"):
        self._session = session
        self._done = False
        self._value = None
        self._error = None

    def _fulfill(self, value) -> None:
        self._done = True
        self._value = value

    def _fail(self, error: BaseException) -> None:
        self._done = True
        self._error = error

    @property
    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._session.flush()
        if self._error is not None:
            raise self._error
        return self._value


class Session:
    """Multi-model registry + micro-batched request path + stats."""

    def __init__(self, config: Optional[NPUConfig] = None,
                 options: Optional[CompilerOptions] = None,
                 cache_dir: Optional[str] = None,
                 max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None,
                 max_batch: int = 8):
        self.cfg = config or NEUTRON_2TOPS
        self.options = options
        self.max_batch = int(max_batch)
        # only forward knobs the caller actually set — the store is
        # process-wide and an omitted knob must not reset prior config
        if cache_dir is not None:
            program_cache_configure(disk_dir=cache_dir)
        if max_entries is not None:
            program_cache_configure(max_entries=max_entries)
        if max_bytes is not None:
            program_cache_configure(max_bytes=max_bytes)
        self._models: Dict[str, CompiledModel] = {}
        self._stats: Dict[str, dict] = {}
        self._pinned: set = set()
        #: request-coalescing queue: model name -> [(feed, ticket), ...]
        self._queue: Dict[str, List[tuple]] = {}
        self._queue_depth = 0

    def _model_stats(self, name: str) -> dict:
        return self._stats.setdefault(name, {
            "requests": 0, "run_s": 0.0,
            "batched_requests": 0, "batches": 0, "max_batch_seen": 0,
            "compiles": {"solved": 0, "memory": 0, "disk": 0,
                         "artifact": 0},
        })

    # -- registry -----------------------------------------------------------
    def add(self, source, name: Optional[str] = None,
            precision: str = "auto",
            options: Optional[CompilerOptions] = None,
            warmup: bool = False, pin: bool = False,
            **kw) -> CompiledModel:
        """Compile (or fetch from the program cache) and register one
        model.  ``precision`` selects the per-model execution precision
        ("auto" / "float32" / "int8"); ``warmup=True`` runs one zero
        input through the program so first-request latency excludes the
        replay's lazy plan lowering; ``pin=True`` marks the model's
        compiled program exempt from in-process LRU eviction."""
        from . import compile as api_compile
        model = api_compile(source, self.cfg,
                            options if options is not None else self.options,
                            precision=precision, **kw)
        name = name or model.name
        self._models[name] = model
        st = self._model_stats(name)
        st["precision"] = model.precision
        st["compile_s"] = model.compile_s
        st["latency_ms"] = model.program.latency_ms()
        st["compiles"][model.cache_tier or "solved"] += 1
        if pin:
            self.pin(name)
        if warmup:
            self.warmup(name)
        return model

    def load(self, path: str, name: Optional[str] = None,
             mmap: bool = True, pin: bool = False) -> CompiledModel:
        """Register a model from an on-disk artifact (no compilation).
        ``mmap=True`` maps the artifact's weight arrays copy-on-write
        instead of reading them into RAM — a fleet of Sessions serving
        the same artifacts shares one page-cache copy per weight."""
        model = CompiledModel.load(path, mmap=mmap)
        name = name or model.name
        self._models[name] = model
        st = self._model_stats(name)
        st["precision"] = model.precision
        st["compile_s"] = 0.0
        st["latency_ms"] = model.program.latency_ms()
        st["compiles"]["artifact"] += 1
        if pin:
            self.pin(name)
        return model

    def warmup(self, name: Optional[str] = None) -> None:
        """Run one all-zeros input through the named model (or all) —
        builds the batch-1 replay plan, so first-request latency is
        pure execution."""
        import numpy as np
        names = [name] if name else list(self._models)
        for n in names:
            m = self._models[n]
            m({t.name: np.zeros(t.shape, dtype=np.float32)
               for t in m.graph.inputs})

    def get(self, name: str) -> CompiledModel:
        return self._models[name]

    __getitem__ = get

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def models(self):
        return list(self._models)

    # -- admission policy ---------------------------------------------------
    def pin(self, name: str) -> None:
        """Exempt this model's compiled program from in-process LRU
        eviction (hot-model admission policy)."""
        model = self._get(name)
        program_cache_pin(model.fingerprint)
        self._pinned.add(name)

    def unpin(self, name: str) -> None:
        model = self._get(name)
        program_cache_unpin(model.fingerprint)
        self._pinned.discard(name)

    def pinned(self) -> List[str]:
        return sorted(self._pinned)

    # -- request path -------------------------------------------------------
    def _get(self, name: str) -> CompiledModel:
        try:
            return self._models[name]
        except KeyError:
            raise KeyError(
                f"model {name!r} not registered "
                f"(have: {sorted(self._models)})") from None

    def run(self, name: str, inputs: Inputs, check: bool = False):
        model = self._get(name)
        t0 = time.monotonic()
        out = model(inputs, check=check)
        st = self._stats[name]
        st["requests"] += 1
        st["run_s"] += time.monotonic() - t0
        return out

    def run_many(self, name: str, requests: List[Inputs],
                 check: bool = False) -> List[dict]:
        """Execute a group of same-model requests as chunked plan
        replays of at most ``max_batch`` requests each."""
        model = self._get(name)
        st = self._stats[name]
        out: List[dict] = []
        t0 = time.monotonic()
        for i in range(0, len(requests), self.max_batch):
            group = requests[i:i + self.max_batch]
            out.extend(model.run_many(group, check=check))
            st["batches"] += 1
            st["batched_requests"] += len(group)
            st["max_batch_seen"] = max(st["max_batch_seen"], len(group))
        st["requests"] += len(requests)
        st["run_s"] += time.monotonic() - t0
        return out

    def submit(self, name: str, inputs: Inputs) -> Ticket:
        """Queue one request for micro-batching.  The request executes
        at the next :meth:`flush` (or transparently when its ticket's
        ``result()`` is read), grouped with every other queued request
        for the same model."""
        self._get(name)                       # fail fast on bad names
        ticket = Ticket(self)
        self._queue.setdefault(name, []).append((inputs, ticket))
        self._queue_depth += 1
        return ticket

    def flush(self) -> int:
        """Drain the coalescing queue: one ``run_many`` per model with
        queued work.  Returns the number of requests executed.

        One model's batch failing fails only *its* tickets (the error
        is stored and re-raised both here and from each ``result()``);
        every other model's requests stay queued for the next flush."""
        executed = 0
        while self._queue:
            name = next(iter(self._queue))
            entries = self._queue.pop(name)
            self._queue_depth -= len(entries)
            try:
                outs = self.run_many(name, [feed for feed, _ in entries])
            except Exception as e:
                for _, ticket in entries:
                    ticket._fail(e)
                raise
            for (_, ticket), out in zip(entries, outs):
                ticket._fulfill(out)
            executed += len(entries)
        return executed

    @property
    def queue_depth(self) -> int:
        return self._queue_depth

    # -- reporting ----------------------------------------------------------
    def stats(self) -> dict:
        models = {}
        for n, s in self._stats.items():
            d = dict(s)
            if n in self._models:
                d["plan"] = self._models[n].plan_cache_info()
            models[n] = d
        return {"models": models,
                "pinned": self.pinned(),
                "queue_depth": self._queue_depth,
                "max_batch": self.max_batch,
                "program_cache": program_cache_info()}

    def report(self) -> str:
        cache = program_cache_info()
        lines = [f"Session: {len(self._models)} model(s), "
                 f"cache {cache['entries']} entries in memory "
                 f"({cache['pinned_entries']} pinned)"
                 + (f", disk tier at {cache['disk_dir']}"
                    if cache["disk_dir"] else ", no disk tier")]
        for n, st in self._stats.items():
            tiers = st["compiles"]
            pin_mark = "*" if n in self._pinned else " "
            lines.append(
                f" {pin_mark}{n:<24} [{st['precision']:>7}]  "
                f"{st['requests']:>5} reqs "
                f"({st['batched_requests']} in {st['batches']} batches)  "
                f"modeled {st['latency_ms']:.3f} ms  "
                f"compiles solved/mem/disk/artifact = "
                f"{tiers['solved']}/{tiers['memory']}/{tiers['disk']}"
                f"/{tiers['artifact']}")
        return "\n".join(lines)
