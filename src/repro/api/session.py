"""Multi-model serving session on top of the two-tier program cache.

A :class:`Session` is the fleet-facing object: a registry of
:class:`~repro.api.compiled.CompiledModel` instances (each with its own
precision) behind one hardware config, one options baseline and one
two-tier (in-process LRU + on-disk artifact) compiled-program cache.
Typical serving flow:

    sess = Session(cache_dir="/var/cache/neutron")
    sess.add("mobilenet_v2", precision="int8")       # precompile
    sess.add("yolov8n_det")                          # float32 fallback
    out = sess.run("mobilenet_v2", image)            # request path
    print(sess.stats())                              # tier hit rates

Every compile inside the session flows through
:func:`repro.core.pipeline.compile_graph`'s two-tier store, so a second
process with the same ``cache_dir`` warm-starts from disk instead of
re-running the CP solver.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from repro.core.npu import NEUTRON_2TOPS, NPUConfig
from repro.core.pipeline import (CompilerOptions, program_cache_configure,
                                 program_cache_info)

from .compiled import CompiledModel, Inputs


class Session:
    """Multi-model registry + per-model serving statistics."""

    def __init__(self, config: Optional[NPUConfig] = None,
                 options: Optional[CompilerOptions] = None,
                 cache_dir: Optional[str] = None,
                 max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        self.cfg = config or NEUTRON_2TOPS
        self.options = options
        # only forward knobs the caller actually set — the store is
        # process-wide and an omitted knob must not reset prior config
        if cache_dir is not None:
            program_cache_configure(disk_dir=cache_dir)
        if max_entries is not None:
            program_cache_configure(max_entries=max_entries)
        if max_bytes is not None:
            program_cache_configure(max_bytes=max_bytes)
        self._models: Dict[str, CompiledModel] = {}
        self._stats: Dict[str, dict] = {}

    # -- registry -----------------------------------------------------------
    def add(self, source, name: Optional[str] = None,
            precision: str = "auto",
            options: Optional[CompilerOptions] = None,
            warmup: bool = False, **kw) -> CompiledModel:
        """Compile (or fetch from the program cache) and register one
        model.  ``precision`` selects the per-model execution precision
        ("auto" / "float32" / "int8"); ``warmup=True`` runs one zero
        input through the program so first-request latency excludes the
        replay's lazy setup."""
        from . import compile as api_compile
        model = api_compile(source, self.cfg,
                            options if options is not None else self.options,
                            precision=precision, **kw)
        name = name or model.name
        self._models[name] = model
        st = self._stats.setdefault(name, {
            "requests": 0, "run_s": 0.0,
            "compiles": {"solved": 0, "memory": 0, "disk": 0,
                         "artifact": 0},
        })
        st["precision"] = model.precision
        st["compile_s"] = model.compile_s
        st["latency_ms"] = model.program.latency_ms()
        st["compiles"][model.cache_tier or "solved"] += 1
        if warmup:
            self.warmup(name)
        return model

    def load(self, path: str, name: Optional[str] = None) -> CompiledModel:
        """Register a model from an on-disk artifact (no compilation)."""
        model = CompiledModel.load(path)
        name = name or model.name
        self._models[name] = model
        st = self._stats.setdefault(name, {
            "requests": 0, "run_s": 0.0,
            "compiles": {"solved": 0, "memory": 0, "disk": 0,
                         "artifact": 0},
        })
        st["precision"] = model.precision
        st["compile_s"] = 0.0
        st["latency_ms"] = model.program.latency_ms()
        st["compiles"]["artifact"] += 1
        return model

    def warmup(self, name: Optional[str] = None) -> None:
        """Run one all-zeros input through the named model (or all)."""
        import numpy as np
        names = [name] if name else list(self._models)
        for n in names:
            m = self._models[n]
            m({t.name: np.zeros(t.shape, dtype=np.float32)
               for t in m.graph.inputs})

    def get(self, name: str) -> CompiledModel:
        return self._models[name]

    __getitem__ = get

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def models(self):
        return list(self._models)

    # -- request path -------------------------------------------------------
    def run(self, name: str, inputs: Inputs, check: bool = False):
        try:
            model = self._models[name]
        except KeyError:
            raise KeyError(
                f"model {name!r} not registered "
                f"(have: {sorted(self._models)})") from None
        t0 = time.monotonic()
        out = model(inputs, check=check)
        st = self._stats[name]
        st["requests"] += 1
        st["run_s"] += time.monotonic() - t0
        return out

    # -- reporting ----------------------------------------------------------
    def stats(self) -> dict:
        return {"models": {n: dict(s) for n, s in self._stats.items()},
                "program_cache": program_cache_info()}

    def report(self) -> str:
        cache = program_cache_info()
        lines = [f"Session: {len(self._models)} model(s), "
                 f"cache {cache['entries']} entries in memory"
                 + (f", disk tier at {cache['disk_dir']}"
                    if cache["disk_dir"] else ", no disk tier")]
        for n, st in self._stats.items():
            tiers = st["compiles"]
            lines.append(
                f"  {n:<24} [{st['precision']:>7}]  "
                f"{st['requests']:>5} reqs  "
                f"modeled {st['latency_ms']:.3f} ms  "
                f"compiles solved/mem/disk/artifact = "
                f"{tiers['solved']}/{tiers['memory']}/{tiers['disk']}"
                f"/{tiers['artifact']}")
        return "\n".join(lines)
