"""Multi-model serving session: micro-batching, deadlines, fault
tolerance.

A :class:`Session` is the fleet-facing object: a registry of
:class:`~repro.api.compiled.CompiledModel` instances (each with its own
precision) behind one hardware config, one options baseline and one
two-tier (in-process LRU + on-disk artifact) compiled-program cache.
Typical serving flow:

    sess = Session(cache_dir="/var/cache/neutron", max_batch=8,
                   workers=2)                         # worker pool
    sess.add("mobilenet_v2", precision="int8", pin=True)  # hot model
    sess.add("yolov8n_det")                               # float32
    out = sess.run("mobilenet_v2", image)         # single request
    outs = sess.run_many("mobilenet_v2", images)  # one plan replay

    t1 = sess.submit("mobilenet_v2", img_a, deadline_ms=50)
    t2 = sess.submit("mobilenet_v2", img_b, deadline_ms=50)
    t1.result(), t2.result()                      # latency-bounded

Requests execute on each model's **compiled replay plan** (lowered
once, batch-vectorized — see :mod:`repro.core.execplan`); the
request-coalescing queue groups same-model submissions into one plan
execution of up to ``max_batch`` requests.

**Robustness contract** (see :mod:`repro.runtime.serving`): every
submitted ticket terminates with a result or a *typed* error.  The
bounded per-model queue sheds load with :class:`~repro.runtime.serving.
Overloaded` (retry-after hint included); tickets whose deadline passes
before execution fail with ``DeadlineExceeded`` instead of running
stale work; a failing plan execution fails only its own batch's
tickets, is retried once (transient faults), and after
``breaker_threshold`` consecutive failures the model's circuit breaker
trips — requests degrade to the interpretive oracle engine (slow but
correct) while a background re-lower probe attempts recovery.  With
``workers > 0`` a :class:`~repro.runtime.serving.ServerPool` serves the
queues: per-worker plan arenas, EDF-within-model / priority-
across-models dispatch, deadline-driven auto-flush, heartbeat-based
hang detection with in-flight re-dispatch and worker recycling.
``workers=("process", n)`` swaps in a :class:`~repro.runtime.procpool.
ProcPool`: each worker is a separate OS *process* mmapping the model
artifacts (crash-fault isolation — a SIGKILL/SIGSEGV/OOM death
re-dispatches the in-flight batch to survivors and respawns off the
request path, with zero ticket loss).

``pin()`` marks a model's compiled program exempt from the in-process
LRU eviction (the admission policy for hot models); pinned counts are
surfaced in ``program_cache_info()`` / :meth:`stats`, which also grows
per-model p50/p99 latency histograms, shed/deadline-miss/degraded
counters and per-worker health.
"""
from __future__ import annotations

import os
import random
import tempfile
import threading
import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.npu import NEUTRON_2TOPS, NPUConfig
from repro.core.pipeline import (CompilerOptions, program_cache_configure,
                                 program_cache_info, program_cache_pin,
                                 program_cache_unpin)
from repro.obs import trace as _trace
from repro.obs.metrics import LogHistogram, MetricsRegistry
from repro.runtime import chaos as _chaos
from repro.runtime.serving import (Cancelled, CircuitBreaker,
                                   DeadlineExceeded, FlushError,
                                   FrameCorrupt, LatencyHistogram,
                                   Overloaded, ServerPool, Ticket,
                                   WorkerCrashed)

from .compiled import CompiledModel, Inputs

#: request errors that are the *caller's* fault (bad shape, bad name):
#: not retried, never counted against the model's circuit breaker.
_CLIENT_ERRORS = (ValueError, TypeError, KeyError)


class Session:
    """Multi-model registry + micro-batched request path + stats."""

    def __init__(self, config: Optional[NPUConfig] = None,
                 options: Optional[CompilerOptions] = None,
                 cache_dir: Optional[str] = None,
                 max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None,
                 max_batch: int = 8,
                 workers: Union[int, Tuple[str, int]] = 0,
                 max_queue: int = 256,
                 linger_ms: float = 2.0,
                 heartbeat_timeout_s: float = 0.5,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 2.0,
                 retry_backoff_ms: float = 10.0,
                 tag: Optional[str] = None):
        self.cfg = config or NEUTRON_2TOPS
        self.options = options
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        self.retry_backoff_s = float(retry_backoff_ms) / 1e3
        #: chaos-attribution tag (fleet replicas pass their replica id
        #: so per-replica faults — silent output corruption — can be
        #: aimed at one session among many in the same process)
        self.tag = tag
        # only forward knobs the caller actually set — the store is
        # process-wide and an omitted knob must not reset prior config
        if cache_dir is not None:
            program_cache_configure(disk_dir=cache_dir)
        if max_entries is not None:
            program_cache_configure(max_entries=max_entries)
        if max_bytes is not None:
            program_cache_configure(max_bytes=max_bytes)
        self._models: Dict[str, CompiledModel] = {}
        self._stats: Dict[str, dict] = {}
        self._stats_lock = threading.Lock()
        self._pinned: set = set()
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._hists: Dict[str, LatencyHistogram] = {}
        #: the session's metrics surface (repro.obs.metrics): the
        #: latency/queue-wait/service histograms live here as families,
        #: every dict counter is mirrored in by a render-time collector,
        #: and Session.metrics() renders the whole registry
        self.registry = MetricsRegistry()
        self._m_latency = self.registry.histogram(
            "repro_request_latency_ms",
            "end-to-end served request latency", ("model",))
        self._m_queue_wait = self.registry.histogram(
            "repro_queue_wait_ms",
            "submit-to-execution queue wait", ("model",))
        self._m_service = self.registry.histogram(
            "repro_batch_service_ms",
            "batch execution (service) time", ("model",))
        self.registry.register_collector(self._collect_metrics)
        #: synchronous-mode coalescing queue: name -> [(feed, ticket)]
        self._queue: Dict[str, List[tuple]] = {}
        self._queue_depth = 0
        self._pool: Optional[ServerPool] = None
        self.closed = False
        #: background half-open recovery probes, one timer per tripped
        #: model (canceled on close)
        self._probe_lock = threading.Lock()
        self._probe_timers: Dict[str, threading.Timer] = {}
        #: artifact spool for process pools (workers mmap models from
        #: here when they were compiled in-session rather than loaded
        #: from an artifact path)
        self._spool_dir: Optional[str] = None
        # workers policy: n (threads, back-compat) or ("thread"|"process", n)
        if isinstance(workers, (tuple, list)):
            pool_mode, n_workers = workers
            n_workers = int(n_workers)
        else:
            pool_mode, n_workers = "thread", int(workers)
        if pool_mode not in ("thread", "process"):
            raise ValueError(
                f"workers mode must be 'thread' or 'process', "
                f"got {pool_mode!r}")
        if n_workers:
            kw = dict(max_batch=self.max_batch, max_queue=self.max_queue,
                      linger_ms=linger_ms,
                      heartbeat_timeout_s=heartbeat_timeout_s,
                      registry=self.registry)
            if pool_mode == "process":
                from repro.runtime.procpool import ProcPool
                self._pool = ProcPool(self._execute_entries,
                                      workers=n_workers, **kw)
            else:
                self._pool = ServerPool(self._execute_entries,
                                        workers=n_workers, **kw)

    @classmethod
    def fleet(cls, replicas: int = 2, **kw) -> "Fleet":  # noqa: F821
        """Construct a :class:`~repro.runtime.fleet.Fleet` of
        ``replicas`` Sessions (each with its own worker pool, modeling
        one host) behind a single health-routed, hedged ``submit()``
        surface.  Keyword arguments are forwarded to
        :class:`~repro.runtime.fleet.Fleet`; per-session knobs
        (``workers``, ``max_batch``, …) reach every replica."""
        from repro.runtime.fleet import Fleet
        return Fleet(replicas=replicas, session_factory=cls, **kw)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Shut the session down: queued-but-unexecuted tickets fail
        with a typed ``WorkerLost`` error (never silently lost)."""
        if self.closed:
            return
        self.closed = True
        with self._probe_lock:
            timers = list(self._probe_timers.values())
            self._probe_timers.clear()
        for t in timers:
            t.cancel()
        if self._pool is not None:
            self._pool.close()
        if self._spool_dir is not None:
            import shutil
            shutil.rmtree(self._spool_dir, ignore_errors=True)
            self._spool_dir = None

    def _model_stats(self, name: str) -> dict:
        return self._stats.setdefault(name, {
            "requests": 0, "run_s": 0.0,
            "batched_requests": 0, "batches": 0, "max_batch_seen": 0,
            "compiles": {"solved": 0, "memory": 0, "disk": 0,
                         "artifact": 0},
            # robustness counters
            "shed": 0, "deadline_misses": 0, "degraded_requests": 0,
            "retries": 0, "submit_retries": 0, "plan_failures": 0,
            "breaker_trips": 0, "recoveries": 0, "failed_recoveries": 0,
            "crash_redispatches": 0, "frame_corrupt": 0, "cancelled": 0,
        })

    def _count(self, name: str, counter: str, n: int = 1) -> None:
        with self._stats_lock:
            self._model_stats(name)[counter] += n

    def _breaker(self, name: str) -> CircuitBreaker:
        br = self._breakers.get(name)
        if br is None:
            br = self._breakers[name] = CircuitBreaker(
                threshold=self.breaker_threshold,
                cooldown_s=self.breaker_cooldown_s, name=name)
        return br

    def _hist(self, name: str) -> LatencyHistogram:
        h = self._hists.get(name)
        if h is None:
            # the registry family child IS the session's histogram —
            # one series, readable both as stats()["latency"] and as
            # the repro_request_latency_ms summary in metrics()
            h = self._hists[name] = self._m_latency.labels(model=name)
        return h

    # -- registry -----------------------------------------------------------
    def _register_with_pool(self, name: str, model: CompiledModel,
                            path: Optional[str],
                            priority: Optional[int]) -> None:
        """Hand a newly registered model to the worker pool: process
        pools need an on-disk artifact (spooled here if the model was
        compiled in-session) for the children to mmap."""
        pool = self._pool
        if pool is None:
            if priority is not None:
                raise ValueError(
                    f"{name}: priority= needs a worker pool "
                    f"(workers > 0)")
            return
        if priority is not None:
            pool.set_priority(name, int(priority))
        if pool.mode != "process":
            return
        if model.semantics is None:
            raise RuntimeError(
                f"{name}: cost-model-only models (dtype-cast graphs) "
                f"have no executable semantics and cannot be served "
                f"by a process pool")
        if path is None:
            if self._spool_dir is None:
                self._spool_dir = tempfile.mkdtemp(
                    prefix="repro-procpool-")
            path = os.path.join(self._spool_dir, f"{name}.rpa")
            model.save(path)
        pool.register_model(name, path)

    def add(self, source, name: Optional[str] = None,
            precision: str = "auto",
            options: Optional[CompilerOptions] = None,
            warmup: bool = False, pin: bool = False,
            priority: Optional[int] = None,
            **kw) -> CompiledModel:
        """Compile (or fetch from the program cache) and register one
        model.  ``precision`` selects the per-model execution precision
        ("auto" / "float32" / "int8"); ``warmup=True`` runs one zero
        input through the program so first-request latency excludes the
        replay's lazy plan lowering; ``pin=True`` marks the model's
        compiled program exempt from in-process LRU eviction;
        ``priority`` assigns the pool dispatch/shedding priority class
        (higher dispatches first).  With a process pool the compiled
        model is spooled to an artifact the worker processes mmap."""
        from . import compile as api_compile
        model = api_compile(source, self.cfg,
                            options if options is not None else self.options,
                            precision=precision, **kw)
        name = name or model.name
        self._models[name] = model
        st = self._model_stats(name)
        st["precision"] = model.precision
        st["compile_s"] = model.compile_s
        st["latency_ms"] = model.program.latency_ms()
        st["compiles"][model.cache_tier or "solved"] += 1
        self._register_with_pool(name, model, None, priority)
        if pin:
            self.pin(name)
        if warmup:
            self.warmup(name)
        return model

    def load(self, path: str, name: Optional[str] = None,
             mmap: bool = True, pin: bool = False,
             priority: Optional[int] = None) -> CompiledModel:
        """Register a model from an on-disk artifact (no compilation).
        ``mmap=True`` maps the artifact's weight arrays copy-on-write
        instead of reading them into RAM — a fleet of Sessions serving
        the same artifacts shares one page-cache copy per weight (as do
        a process pool's workers, which mmap this same artifact)."""
        model = CompiledModel.load(path, mmap=mmap)
        name = name or model.name
        self._models[name] = model
        st = self._model_stats(name)
        st["precision"] = model.precision
        st["compile_s"] = 0.0
        st["latency_ms"] = model.program.latency_ms()
        st["compiles"]["artifact"] += 1
        self._register_with_pool(name, model, path, priority)
        if pin:
            self.pin(name)
        return model

    def warmup(self, name: Optional[str] = None) -> None:
        """Run one all-zeros input through the named model (or all) —
        builds the batch-1 replay plan, so first-request latency is
        pure execution."""
        names = [name] if name else list(self._models)
        for n in names:
            m = self._models[n]
            m({t.name: np.zeros(t.shape, dtype=np.float32)
               for t in m.graph.inputs})

    def get(self, name: str) -> CompiledModel:
        return self._models[name]

    __getitem__ = get

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def models(self):
        return list(self._models)

    # -- admission policy ---------------------------------------------------
    def pin(self, name: str) -> None:
        """Exempt this model's compiled program from in-process LRU
        eviction (hot-model admission policy)."""
        model = self._get(name)
        program_cache_pin(model.fingerprint)
        self._pinned.add(name)

    def unpin(self, name: str) -> None:
        model = self._get(name)
        program_cache_unpin(model.fingerprint)
        self._pinned.discard(name)

    def pinned(self) -> List[str]:
        return sorted(self._pinned)

    # -- request path -------------------------------------------------------
    def _get(self, name: str) -> CompiledModel:
        try:
            return self._models[name]
        except KeyError:
            raise KeyError(
                f"model {name!r} not registered "
                f"(have: {sorted(self._models)})") from None

    def run(self, name: str, inputs: Inputs, check: bool = False):
        model = self._get(name)
        t0 = time.monotonic()
        out = model(inputs, check=check)
        dt = time.monotonic() - t0
        with self._stats_lock:
            st = self._model_stats(name)
            st["requests"] += 1
            st["run_s"] += dt
        self._hist(name).record(dt * 1e3)
        return out

    def run_many(self, name: str, requests: List[Inputs],
                 check: bool = False) -> List[dict]:
        """Execute a group of same-model requests as chunked plan
        replays of at most ``max_batch`` requests each."""
        model = self._get(name)
        out: List[dict] = []
        t0 = time.monotonic()
        nb = nr = 0
        mx = 0
        for i in range(0, len(requests), self.max_batch):
            group = requests[i:i + self.max_batch]
            out.extend(model.run_many(group, check=check))
            nb += 1
            nr += len(group)
            mx = max(mx, len(group))
        dt = time.monotonic() - t0
        with self._stats_lock:
            st = self._model_stats(name)
            st["batches"] += nb
            st["batched_requests"] += nr
            st["max_batch_seen"] = max(st["max_batch_seen"], mx)
            st["requests"] += len(requests)
            st["run_s"] += dt
        return out

    def submit(self, name: str, inputs: Inputs,
               deadline_ms: Optional[float] = None,
               retries: int = 0,
               retry_cap_ms: float = 250.0) -> Ticket:
        """Queue one request for micro-batching and return its
        :class:`Ticket`.

        ``deadline_ms`` bounds end-to-end latency: the batch carrying
        this request auto-flushes early enough to make the deadline
        (pooled sessions), and a ticket whose deadline passes before it
        executes fails with ``DeadlineExceeded`` instead of running
        stale work.  When the model's bounded queue (``max_queue``) is
        full the request is shed with :class:`Overloaded` carrying a
        retry-after hint.

        ``retries=N`` turns the shed into client-side retry: each
        :class:`Overloaded` is retried after an exponential backoff
        with *full jitter* — ``sleep(U(0, min(cap, hint * 2**attempt)))``
        seeded from the shed hint's p50-derived ``retry_after_ms`` and
        capped at ``retry_cap_ms`` — so synchronized retry storms decor-
        relate.  The deadline is absolute: backoff spends it, it never
        extends it.  Retries count into ``repro_retries_total``."""
        self._get(name)                       # fail fast on bad names
        now = _chaos.now()
        deadline = None
        if deadline_ms is not None:
            deadline = now + float(deadline_ms) / 1e3
        for attempt in range(int(retries)):
            try:
                return self._submit_once(name, inputs, deadline,
                                         deadline_ms)
            except Overloaded as e:
                self._count(name, "submit_retries")
                base = min(float(retry_cap_ms),
                           max(1.0, e.retry_after_ms) * (2 ** attempt))
                delay_s = random.random() * base / 1e3
                if deadline is not None and \
                        _chaos.now() + delay_s >= deadline:
                    raise          # backoff would outlive the deadline
                time.sleep(delay_s)
        return self._submit_once(name, inputs, deadline, deadline_ms)

    def _submit_once(self, name: str, inputs: Inputs,
                     deadline: Optional[float],
                     deadline_ms: Optional[float]) -> Ticket:
        now = _chaos.now()
        ticket = Ticket(self, name, deadline)
        with _trace.maybe_span("submit", "serving",
                               trace_id=ticket.trace_id, model=name,
                               deadline_ms=deadline_ms):
            if deadline is not None and deadline <= now:
                self._count(name, "deadline_misses")
                ticket._fail(DeadlineExceeded(name, 0.0))
                return ticket
            if self._pool is not None:
                # the pool counts shed/deadline misses itself; stats()
                # merges
                self._pool.submit(name, inputs, ticket)
                return ticket
            q = self._queue.setdefault(name, [])
            if len(q) >= self.max_queue:
                self._count(name, "shed")
                _trace.instant("shed", "serving",
                               trace_id=ticket.trace_id,
                               args={"model": name, "depth": len(q)})
                st = self._stats.get(name) or {}
                est = st.get("latency_ms", 10.0) or 10.0
                raise Overloaded(name, len(q), max(
                    1.0, est * (len(q) / max(1, self.max_batch))))
            q.append((inputs, ticket))
            self._queue_depth += 1
            return ticket

    def _resolve(self, ticket: Ticket, timeout: Optional[float]) -> None:
        """Block until a ticket terminates: waits on the worker pool, or
        drains *only that ticket's model* in synchronous mode (a slow
        unrelated model never blocks an independent result)."""
        if self._pool is not None:
            ticket._event.wait(timeout)
            return
        try:
            self.flush(ticket.name)
        except FlushError:
            pass          # the ticket's own stored error is re-raised

    def _cancel(self, ticket: Ticket) -> bool:
        """:meth:`Ticket.cancel` body: settle the ticket ``Cancelled``
        (first-wins — a real result that already landed stands) and
        free its queue slot so a cancelled request stops holding
        admission capacity."""
        won = ticket._fail(Cancelled(ticket.name))
        if won:
            self._count(ticket.name, "cancelled")
            _trace.instant("cancel", "serving", trace_id=ticket.trace_id,
                           args={"model": ticket.name})
        # purge the queue slot either way: a settled ticket would be
        # skipped on claim, but its heap entry still occupies capacity
        if self._pool is not None:
            self._pool.discard(ticket.name, ticket)
        else:
            q = self._queue.get(ticket.name)
            if q:
                n0 = len(q)
                q[:] = [e for e in q if e[1] is not ticket]
                self._queue_depth -= n0 - len(q)
        return won

    # -- robust batch execution (shared by sync flush and the pool) ---------
    def _plan_run(self, name: str, model: CompiledModel, feeds,
                  worker=None, trace_ids=None):
        c = _chaos.active()
        if c is not None:
            c.check_plan(name)
        pool = self._pool
        if pool is not None and pool.mode == "process" \
                and worker is not None:
            # normalize here (run_many's client-error contract) so the
            # child only ever sees clean single-sample dicts
            feeds = [model._normalize(f) for f in feeds]
            for f in feeds:
                if model._batch_size(f) is not None:
                    raise ValueError(
                        f"{name}: run_many takes single-sample requests"
                        f" — pass a batched array to __call__ instead")
            return pool.remote_run(worker, name, feeds,
                                   trace_ids=trace_ids)
        return model.run_many(feeds, owner=worker)

    def _degraded_run(self, model: CompiledModel, feeds) -> List[dict]:
        """Breaker-open path: serve the whole batch as *one* stacked
        interpretive replay (not a per-sample loop of calls), split
        back per request."""
        feeds = [model._normalize(f) for f in feeds]
        if len(feeds) == 1:
            return [model(feeds[0], engine="interp")]
        stacked = {t.name: np.stack([np.asarray(f[t.name])
                                     for f in feeds])
                   for t in model.graph.inputs}
        res = model(stacked, engine="interp")
        return [{k: v[i] for k, v in res.items()}
                for i in range(len(feeds))]

    # -- breaker recovery (background probe, off the request path) ----------
    def _schedule_probe(self, name: str, delay_s: float) -> None:
        """Arm (at most) one background re-lower+verify probe timer for
        a tripped model — recovery no longer piggybacks on request
        batches, so an idle model heals too."""
        if self.closed:
            return
        with self._probe_lock:
            if name in self._probe_timers:
                return
            t = threading.Timer(max(0.01, delay_s), self._probe,
                                args=(name,))
            t.daemon = True
            self._probe_timers[name] = t
            t.start()

    def _probe(self, name: str) -> None:
        """Half-open probe body: re-lower the plan from scratch and
        verify it against the interpretive oracle; success closes the
        breaker, failure re-opens it and re-arms the timer."""
        with self._probe_lock:
            self._probe_timers.pop(name, None)
        if self.closed:
            return
        model = self._models.get(name)
        br = self._breakers.get(name)
        if model is None or br is None:
            return
        if not br.try_probe():
            if br.state == "open":     # cooldown not yet elapsed
                self._schedule_probe(name, self.breaker_cooldown_s / 2)
            return
        try:
            c = _chaos.active()
            if c is not None:
                c.check_plan(name)
            model.invalidate_plans()
            feed = {t.name: np.zeros(t.shape, dtype=np.float32)
                    for t in model.graph.inputs}
            model.verify(feed)
        except Exception:
            br.probe_failed()
            self._count(name, "failed_recoveries")
            self._schedule_probe(name, self.breaker_cooldown_s)
        else:
            br.probe_succeeded()
            self._count(name, "recoveries")

    def _crash_redispatch(self, name: str, entries,
                          err: WorkerCrashed) -> None:
        """A worker *process* died with this batch in flight: hand the
        still-live entries back to the pool for the survivors.  No
        ticket fails, nothing counts against the breaker — the crash is
        a fault-domain event, not a model fault (first-fulfillment-wins
        tickets settle any duplicated work)."""
        self._count(name, "crash_redispatches")
        _trace.instant("worker_crashed", "fault",
                       args={"model": name, "worker": err.worker,
                             "n": len(entries)})
        if self._pool is not None:
            self._pool.redispatch(name, entries, err.worker)
        else:                      # sync session: no pool to re-home to
            for _, ticket in entries:
                ticket._fail(err)
        return None

    def _frame_redispatch(self, name: str, entries,
                          err: FrameCorrupt) -> None:
        """A pipe frame failed its CRC: the batch's bytes are
        untrusted but the worker and its stream are intact (the
        transport is length-prefixed — corruption can't desync it).
        Re-dispatch the batch so a healthy worker serves it; no ticket
        fails, nothing counts against the breaker, nobody recycles."""
        self._count(name, "frame_corrupt")
        _trace.instant("frame_redispatch", "fault",
                       args={"model": name, "worker": err.worker,
                             "n": len(entries)})
        if self._pool is not None:
            self._pool.redispatch(name, entries, err.worker)
        else:                      # sync session: no pool to re-home to
            for _, ticket in entries:
                ticket._fail(err)
        return None

    def _execute_entries(self, name: str, entries, worker=None
                         ) -> Optional[BaseException]:
        """Execute one claimed batch, fulfilling or failing every ticket
        in ``entries``; never raises.  The degradation ladder: plan
        engine -> one retry with backoff (transient faults) -> circuit
        breaker trips after K consecutive batch failures -> interpretive
        oracle engine (slow but correct) until a re-lower probe
        recovers.  Returns the batch error, if any."""
        model = self._models[name]
        br = self._breaker(name)
        feeds = [feed for feed, _ in entries]
        trace_ids = [t.trace_id for _, t in entries]
        outs = None
        err: Optional[BaseException] = None
        engine = "plan"
        tracer = _trace.active()
        t0 = time.monotonic()
        if tracer is not None:
            # queue wait: submit (on the caller's thread) -> execution
            # start, as async b/e pairs keyed by trace id so the
            # cross-thread interval never distorts thread nesting
            for _, ticket in entries:
                tracer.complete("queue_wait", "async:serving",
                                ticket.submitted_at, t0,
                                trace_id=ticket.trace_id,
                                args={"model": name})
        for _, ticket in entries:
            self._m_queue_wait.observe(
                (t0 - ticket.submitted_at) * 1e3, model=name)
        if br.allow_plan():
            try:
                outs = self._plan_run(name, model, feeds, worker,
                                      trace_ids)
            except WorkerCrashed as e:
                return self._crash_redispatch(name, entries, e)
            except FrameCorrupt as e:
                return self._frame_redispatch(name, entries, e)
            except _CLIENT_ERRORS as e:
                err = e
            except Exception as e:
                # transient server-side fault: one retry with backoff
                self._count(name, "retries")
                time.sleep(self.retry_backoff_s)
                try:
                    outs = self._plan_run(name, model, feeds, worker,
                                          trace_ids)
                except WorkerCrashed as e2:
                    return self._crash_redispatch(name, entries, e2)
                except FrameCorrupt as e2:
                    return self._frame_redispatch(name, entries, e2)
                except Exception as e2:
                    err = e2
            if outs is not None:
                br.record_success()
            elif not isinstance(err, _CLIENT_ERRORS):
                self._count(name, "plan_failures")
                if br.record_failure():
                    self._count(name, "breaker_trips")
                    self._schedule_probe(name, self.breaker_cooldown_s)
        else:
            # breaker open: serve correct (oracle) outputs, slowly,
            # instead of failing — graceful degradation (the recovery
            # probe runs on its own timer, never on this request path)
            engine = "interp"
            try:
                outs = self._degraded_run(model, feeds)
                self._count(name, "degraded_requests", len(feeds))
            except _CLIENT_ERRORS as e:
                err = e
            except Exception as e:
                err = e
                br.record_failure()
            self._schedule_probe(name, self.breaker_cooldown_s)
        dt = time.monotonic() - t0
        self._m_service.observe(dt * 1e3, model=name)
        if tracer is not None:
            tracer.complete("batch", "serving", t0, t0 + dt,
                            args={"model": name, "n": len(entries),
                                  "engine": engine,
                                  "ok": err is None})
        with self._stats_lock:
            st = self._model_stats(name)
            st["batches"] += 1
            st["batched_requests"] += len(entries)
            st["max_batch_seen"] = max(st["max_batch_seen"], len(entries))
            st["requests"] += len(entries)
            st["run_s"] += dt
            st["engine"] = engine
        if err is not None:
            for _, ticket in entries:
                ticket._fail(err)
            return err
        c = _chaos.active()
        if c is not None and c.maybe_corrupt_output(name, self.tag):
            # silent corruption: serve *wrong bytes* with no error —
            # the fault class only the fleet's interp-oracle audit
            # sampler can catch (and quarantine the replica for)
            outs = [_chaos.flip_outputs(o) for o in outs]
        hist = self._hist(name)
        done_t = time.monotonic()
        for (_, ticket), out in zip(entries, outs):
            if ticket._fulfill(out):
                hist.record((done_t - ticket.submitted_at) * 1e3)
                if tracer is not None:
                    # one span per request over its execution window,
                    # carrying the trace id — the cross-thread hop the
                    # exporter stitches flow arrows through
                    tracer.complete("serve", "serving", t0, done_t,
                                    trace_id=ticket.trace_id,
                                    args={"model": name,
                                          "engine": engine})
        return None

    def flush(self, name: Optional[str] = None, timeout: float = 60.0
              ) -> int:
        """Drain the coalescing queue — all models, or just ``name``.
        Returns the number of requests executed.

        Every model's queue is drained even when an earlier model's
        batch fails: one aggregated :class:`FlushError` (mapping each
        failed model to its typed error) is raised *after* the drain,
        so one bad model never strands another model's tickets.
        Expired tickets fail with ``DeadlineExceeded`` without
        executing.  On pooled sessions this is a barrier: it waits for
        the workers to drain the selected queues."""
        if self._pool is not None:
            if not self._pool.drain(None if name is None else {name},
                                    timeout=timeout):
                raise FlushError({name or "*": TimeoutError(
                    f"pool did not drain within {timeout}s")})
            return 0
        executed = 0
        errors: Dict[str, BaseException] = {}
        names = list(self._queue) if name is None else \
            ([name] if name in self._queue else [])
        for n in names:
            entries = self._queue.pop(n, [])
            self._queue_depth -= len(entries)
            now = _chaos.now()
            live = []
            for feed, ticket in entries:
                if ticket.deadline is not None and now > ticket.deadline:
                    self._count(n, "deadline_misses")
                    ticket._fail(DeadlineExceeded(
                        n, (now - ticket.deadline) * 1e3))
                else:
                    live.append((feed, ticket))
            for i in range(0, len(live), self.max_batch):
                group = live[i:i + self.max_batch]
                err = self._execute_entries(n, group)
                if err is not None:
                    errors[n] = err
                else:
                    executed += len(group)
        if errors:
            raise FlushError(errors)
        return executed

    @property
    def queue_depth(self) -> int:
        if self._pool is not None:
            return self._pool.queue_depth()
        return self._queue_depth

    # -- metrics exposition -------------------------------------------------
    _BREAKER_STATES = {"closed": 0, "half_open": 1, "open": 2}
    _MODEL_COUNTERS = (
        ("requests", "repro_requests_total", "requests served"),
        ("run_s", "repro_run_seconds_total", "wall time executing"),
        ("batches", "repro_batches_total", "batches executed"),
        ("batched_requests", "repro_batched_requests_total",
         "requests served through batches"),
        ("shed", "repro_shed_total", "requests shed by admission control"),
        ("deadline_misses", "repro_deadline_misses_total",
         "tickets expired before execution"),
        ("degraded_requests", "repro_degraded_requests_total",
         "requests served by the interpretive oracle (breaker open)"),
        ("retries", "repro_retries_total",
         "retries: transient batch + client-side submit"),
        ("submit_retries", "repro_submit_retries_total",
         "client-side submit retries after Overloaded sheds"),
        ("cancelled", "repro_cancelled_total",
         "tickets cancelled by the caller"),
        ("frame_corrupt", "repro_frame_corrupt_total",
         "batches re-dispatched after a corrupt pipe frame"),
        ("plan_failures", "repro_plan_failures_total",
         "plan-engine batch failures"),
        ("breaker_trips", "repro_breaker_trips_total",
         "circuit breaker trips"),
        ("recoveries", "repro_recoveries_total",
         "successful re-lower recovery probes"),
        ("failed_recoveries", "repro_failed_recoveries_total",
         "failed re-lower recovery probes"),
        ("crash_redispatches", "repro_crash_redispatches_total",
         "batches re-dispatched after a worker-process crash"),
    )

    def _collect_metrics(self) -> None:
        """Render-time collector: mirror every dict-based counter — the
        per-model stats, the breaker states, the pool's counters and
        worker health, the program cache's tier stats — into registry
        families.  The dicts stay the source of truth (and the
        ``stats()`` surface); the registry is the exposition surface."""
        reg = self.registry
        pool = self._pool
        with self._stats_lock:
            snap = {n: dict(s) for n, s in self._stats.items()}
        for key, metric, help in self._MODEL_COUNTERS:
            fam = reg.counter(metric, help, ("model",))
            for n, st in snap.items():
                v = st.get(key, 0)
                if key == "shed" and pool is not None:
                    v += pool.shed.get(n, 0)
                elif key == "deadline_misses" and pool is not None:
                    v += pool.deadline_misses.get(n, 0)
                elif key == "retries":
                    # repro_retries_total is the satellite's umbrella:
                    # transient batch retries + client submit retries
                    # (broken out in repro_submit_retries_total)
                    v += st.get("submit_retries", 0)
                fam.set_total(v, model=n)
        compiles = reg.counter("repro_compiles_total",
                               "model compiles by cache tier",
                               ("model", "tier"))
        modeled = reg.gauge("repro_modeled_latency_ms",
                            "cost-model predicted latency", ("model",))
        for n, st in snap.items():
            for tier, v in st.get("compiles", {}).items():
                compiles.set_total(v, model=n, tier=tier)
            if "latency_ms" in st:
                modeled.set(st["latency_ms"], model=n)
        breaker = reg.gauge(
            "repro_breaker_state",
            "circuit breaker state (0=closed 1=half_open 2=open)",
            ("model",))
        for n, br in self._breakers.items():
            breaker.set(self._BREAKER_STATES.get(br.state, -1), model=n)
        reg.gauge("repro_queue_depth",
                  "requests queued, all models").set(self.queue_depth)
        reg.gauge("repro_pinned_models",
                  "models pinned in the program cache"
                  ).set(len(self._pinned))
        info = program_cache_info()
        cache_ev = reg.counter("repro_program_cache_total",
                               "program cache events", ("event",))
        for ev in ("mem_hits", "mem_misses", "mem_evictions",
                   "disk_hits", "disk_misses", "disk_writes",
                   "disk_rejects", "disk_evictions"):
            cache_ev.set_total(info.get(ev, 0), event=ev)
        cache_sz = reg.gauge("repro_program_cache_entries",
                             "programs cached", ("tier",))
        cache_sz.set(info.get("entries", 0), tier="memory")
        cache_sz.set(info.get("disk_entries", 0), tier="disk")
        cache_b = reg.gauge("repro_program_cache_bytes",
                            "program cache resident bytes", ("tier",))
        cache_b.set(info.get("bytes", 0), tier="memory")
        cache_b.set(info.get("disk_bytes", 0), tier="disk")
        if pool is not None:
            pc = reg.counter("repro_pool_total",
                             "worker pool events", ("event",))
            for ev, v in pool.counters.items():
                pc.set_total(v, event=ev)
            reg.gauge("repro_pool_workers", "live pool workers").set(
                len([w for w in pool._workers.values()
                     if not w.abandoned]))
            alive = reg.gauge("repro_worker_alive",
                              "worker thread liveness", ("worker",))
            wbatch = reg.counter("repro_worker_batches_total",
                                 "batches served per worker", ("worker",))
            wreq = reg.counter("repro_worker_requests_total",
                               "requests served per worker", ("worker",))
            wpid = None
            if pool.mode == "process":
                wpid = reg.gauge("repro_worker_pid",
                                 "worker process id (-1 = not ready)",
                                 ("worker",))
            for wid, h in pool.worker_health().items():
                alive.set(1 if h["alive"] and not h["abandoned"] else 0,
                          worker=wid)
                wbatch.set_total(h["batches"], worker=wid)
                wreq.set_total(h["requests"], worker=wid)
                if wpid is not None:
                    wpid.set(h.get("pid") or -1, worker=wid)

    def metrics(self) -> str:
        """The session's metrics registry as Prometheus text exposition
        — request latency / queue wait / batch service summaries,
        shed/deadline/breaker/retry counters, program-cache tier stats,
        pool counters and worker health."""
        return self.registry.render()

    # -- reporting ----------------------------------------------------------
    def stats(self) -> dict:
        pool = self._pool
        models = {}
        with self._stats_lock:
            snap = {n: dict(s) for n, s in self._stats.items()}
        for n, d in snap.items():
            if n in self._models:
                d["plan"] = self._models[n].plan_cache_info()
            if n in self._breakers:
                d["breaker"] = self._breakers[n].snapshot()
            if n in self._hists:
                d["latency"] = self._hists[n].snapshot()
            if pool is not None:
                d["shed"] += pool.shed.get(n, 0)
                d["deadline_misses"] += pool.deadline_misses.get(n, 0)
            models[n] = d
        out = {"models": models,
               "pinned": self.pinned(),
               "queue_depth": self.queue_depth,
               "max_batch": self.max_batch,
               "max_queue": self.max_queue,
               "program_cache": program_cache_info()}
        if pool is not None:
            out["pool"] = pool.stats()
            out["workers"] = pool.worker_health()
        return out

    def report(self) -> str:
        cache = program_cache_info()
        lines = [f"Session: {len(self._models)} model(s), "
                 f"cache {cache['entries']} entries in memory "
                 f"({cache['pinned_entries']} pinned)"
                 + (f", disk tier at {cache['disk_dir']}"
                    if cache["disk_dir"] else ", no disk tier")]
        stats = self.stats()["models"]
        for n, st in stats.items():
            tiers = st["compiles"]
            pin_mark = "*" if n in self._pinned else " "
            lines.append(
                f" {pin_mark}{n:<24} [{st['precision']:>7}]  "
                f"{st['requests']:>5} reqs "
                f"({st['batched_requests']} in {st['batches']} batches)  "
                f"modeled {st['latency_ms']:.3f} ms  "
                f"compiles solved/mem/disk/artifact = "
                f"{tiers['solved']}/{tiers['memory']}/{tiers['disk']}"
                f"/{tiers['artifact']}")
            lat = st.get("latency")
            br = st.get("breaker")
            if lat and lat["count"]:
                lines.append(
                    f"   {'':24} served p50 {lat['p50_ms']:.2f} ms / "
                    f"p99 {lat['p99_ms']:.2f} ms"
                    + (f"  breaker {br['state']}"
                       f" (trips {br['trips']})" if br else "")
                    + (f"  shed {st['shed']}" if st["shed"] else "")
                    + (f"  deadline-miss {st['deadline_misses']}"
                       if st["deadline_misses"] else "")
                    + (f"  degraded {st['degraded_requests']}"
                       if st["degraded_requests"] else ""))
            qw = self._m_queue_wait.labels(model=n)
            sv = self._m_service.labels(model=n)
            if qw.count and sv.count:
                # where a request's time went: waiting for its batch to
                # form vs executing in it
                lines.append(
                    f"   {'':24} breakdown queue-wait p50 "
                    f"{qw.percentile(50):.2f} / p99 "
                    f"{qw.percentile(99):.2f} ms  |  service p50 "
                    f"{sv.percentile(50):.2f} / p99 "
                    f"{sv.percentile(99):.2f} ms")
        if self._pool is not None:
            ps = self._pool.stats()
            lines.append(
                f"  pool: {ps['workers']} workers, "
                f"{ps['dispatched_batches']} batches dispatched, "
                f"{ps['recycled_workers']} recycled, "
                f"{ps['redispatched_batches']} re-dispatched, "
                f"{ps['speculative_backups']} speculative backups")
        return "\n".join(lines)
