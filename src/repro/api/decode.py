"""Streaming LM decode serving — compile once, reuse per token.

:class:`DecodeSession` is the serving loop of the causal-operator
subsystem: it compiles the prefill and single-token decode graphs of
:mod:`repro.frontends.lm` once per (sequence, KV-bucket) shape and then
streams tokens by replaying the *same* cached per-step
:class:`~repro.core.execplan.ExecPlan` every token — zero re-lowering
after warmup (``CompiledModel._plan_stats['builds']`` is frozen; the
decode bench and ``tests/test_lm_compile.py`` assert it).

Per-request state is the KV cache: a dict of float32 cache arrays keyed
by the graph's cache-*input* names.  Every step marshals them through
the decode plan's arena (cache inputs are arena slots like any other
activation), and the step's appended cache *outputs* — also arena
slots, copied out on return — become the request's state for the next
token, so concurrent requests never share mutable cache storage.

Sequence-position bucketing: a request is served at the smallest
configured KV bucket that fits its position.  The bucket size enters
the graph fingerprint (cache shapes + each attention op's ``kv_len``
attr), so the compile-pipeline cache keys programs per bucket; crossing
a boundary copies the cache forward into the next bucket's zeros and
switches to that bucket's compiled model.  Weights are shared across
buckets by the builder's deterministic naming, so bucket growth is a
cache copy, not a recompile of anything previously warm.

Per-token observability: when :mod:`repro.obs.trace` is armed, every
prefill and decode step emits a span carrying the request's trace id
(minted at :meth:`prefill`), so one generation can be followed
token-by-token through the Chrome trace export.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.obs import trace as _trace

_rids = itertools.count(1)


@dataclass
class _Request:
    rid: str
    trace_id: int
    bucket: int
    pos: int                               # tokens currently in cache
    caches: Dict[str, np.ndarray]          # cache-input name -> float32
    tokens: List[int] = field(default_factory=list)  # prompt + generated


class DecodeSession:
    """Compile-and-stream serving for the tiny LM decoder.

    ::

        sess = DecodeSession(precision="int8")
        rid, tok = sess.prefill([3, 17, 42])
        for tok in sess.stream(rid, max_new_tokens=16):
            ...
    """

    def __init__(self, spec=None, precision: str = "float32",
                 config=None, options=None, seed: int = 0,
                 buckets: Optional[Sequence[int]] = None,
                 cache: bool = True):
        from repro.frontends import lm
        self._lm = lm
        self.spec = spec or lm.tiny_spec()
        self.precision = precision
        self.config = config
        self.options = options
        self.seed = seed
        self.buckets = tuple(buckets or lm.SEQ_BUCKETS)
        self._cache = cache
        self._models: Dict[tuple, object] = {}   # (seq, kv) -> CompiledModel
        self._requests: Dict[str, _Request] = {}
        self._emb = lm.embedding_table(self.spec, seed)

    # -- compiled-model pool ------------------------------------------------
    def model(self, seq: int, kv_len: int):
        """The compiled model serving (seq, kv_len) — compiled on first
        use, then reused for every request at that shape (its per-step
        ExecPlan is cached inside the CompiledModel)."""
        key = (seq, kv_len)
        m = self._models.get(key)
        if m is None:
            with _trace.maybe_span("lm.compile", "serve",
                                   seq=seq, kv=kv_len):
                m = self._lm.compile_decoder(
                    self.spec, seq, kv_len, precision=self.precision,
                    config=self.config, options=self.options,
                    seed=self.seed, cache=self._cache)
            self._models[key] = m
        return m

    def _run(self, m, feed: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        return m(feed)            # plan engine; unbatched shapes

    # -- request lifecycle --------------------------------------------------
    def prefill(self, prompt_ids: Sequence[int],
                rid: Optional[str] = None) -> tuple:
        """Run the prompt through the prefill graph; returns
        ``(rid, first_token)`` with the request's KV caches populated at
        rows ``[0, len(prompt))``.

        The prompt is right-padded with zero embeddings up to the
        prefill sequence bucket; padded rows are invisible by
        construction — the causal mask hides rows past ``pos`` and
        every later decode step overwrites its own cache row before
        unmasking it."""
        prompt = [int(t) for t in prompt_ids]
        if not prompt:
            raise ValueError("prefill needs at least one prompt token")
        p = len(prompt)
        if p + 1 > self.buckets[-1]:
            raise ValueError(
                f"prompt of {p} tokens exceeds the largest KV bucket "
                f"({self.buckets[-1]}) — raise `buckets`")
        rid = rid or f"req-{next(_rids)}"
        if rid in self._requests:
            raise ValueError(f"request {rid!r} already active")
        trace_id = _trace.new_trace_id()
        kv = self._lm.bucket_for(p + 1, self.buckets)
        sq = self._lm.bucket_for(p, self.buckets)
        m = self.model(sq, kv)
        g = m.graph
        io = self._lm.cache_io(g)

        x = np.zeros((sq, 1, self.spec.d_model), np.float32)
        x[:p] = self._lm.embed(self._emb, prompt)
        feed: Dict[str, np.ndarray] = {
            "x": x, "pos": np.zeros((1, 1, 1), np.float32)}
        for ci in io:
            feed[ci] = np.zeros(g.tensors[ci].shape, np.float32)

        tr = _trace.active()
        t0 = tr.clock() if tr else 0.0
        out = self._run(m, feed)
        if tr:
            tr.complete("lm.prefill", "serve", t0, trace_id=trace_id,
                        args={"rid": rid, "tokens": p, "bucket": kv})

        caches = {ci: np.asarray(out[co], np.float32)
                  for ci, co in io.items()}
        logits = out[self._lm.logits_name(g)]
        tok = int(np.argmax(logits[p - 1, 0]))      # last real row
        self._requests[rid] = _Request(
            rid=rid, trace_id=trace_id, bucket=kv, pos=p,
            caches=caches, tokens=prompt + [tok])
        return rid, tok

    def step(self, rid: str) -> int:
        """One greedy decode step: feed the request's last token through
        the cached single-token plan, append its K/V at row ``pos``,
        advance, and return the argmax token."""
        r = self._requests[rid]
        if r.pos + 1 > self.buckets[-1]:
            raise RuntimeError(
                f"{rid}: KV capacity exhausted at {r.pos} tokens "
                f"(largest bucket {self.buckets[-1]})")
        if r.pos + 1 > r.bucket:
            self._grow(r)
        m = self.model(1, r.bucket)
        g = m.graph
        io = self._lm.cache_io(g)
        feed: Dict[str, np.ndarray] = {
            "x": self._lm.embed(self._emb, [r.tokens[-1]]),
            "pos": np.full((1, 1, 1), float(r.pos), np.float32)}
        feed.update(r.caches)

        tr = _trace.active()
        t0 = tr.clock() if tr else 0.0
        out = self._run(m, feed)
        tok = int(np.argmax(out[self._lm.logits_name(g)][0, 0]))
        if tr:
            tr.complete("lm.decode_step", "serve", t0,
                        trace_id=r.trace_id,
                        args={"rid": rid, "pos": r.pos, "token": tok})

        r.caches = {ci: np.asarray(out[co], np.float32)
                    for ci, co in io.items()}
        r.pos += 1
        r.tokens.append(tok)
        return tok

    def _grow(self, r: _Request) -> None:
        """Copy the request's caches into the next bucket's zeros and
        re-target its compiled model (weights shared across buckets, so
        nothing warm recompiles)."""
        new_kv = self._lm.bucket_for(r.pos + 1, self.buckets)
        grown: Dict[str, np.ndarray] = {}
        for ci, arr in r.caches.items():
            big = np.zeros((new_kv,) + arr.shape[1:], np.float32)
            big[:arr.shape[0]] = arr
            grown[ci] = big
        _trace.instant("lm.bucket_grow", "serve", trace_id=r.trace_id,
                       args={"rid": r.rid, "from": r.bucket, "to": new_kv})
        r.caches = grown
        r.bucket = new_kv

    def stream(self, rid: str, max_new_tokens: int) -> Iterator[int]:
        """Yield up to ``max_new_tokens`` greedy tokens for an active
        request (the prefill's first token was already returned)."""
        for _ in range(max_new_tokens):
            yield self.step(rid)

    def generate(self, prompt_ids: Sequence[int],
                 max_new_tokens: int = 8) -> List[int]:
        """Prefill + decode loop; returns the generated tokens (the
        prefill's first token included).  The request is closed when
        done."""
        rid, tok = self.prefill(prompt_ids)
        toks = [tok]
        try:
            toks.extend(self.stream(rid, max_new_tokens - 1))
        finally:
            self.finish(rid)
        return toks

    def finish(self, rid: str) -> None:
        self._requests.pop(rid, None)

    # -- reporting ----------------------------------------------------------
    def active_requests(self) -> List[str]:
        return sorted(self._requests)

    def tokens(self, rid: str) -> List[int]:
        return list(self._requests[rid].tokens)

    def stats(self) -> Dict[str, object]:
        """Per-compiled-model plan-cache statistics — the decode bench's
        zero-relowering gate reads ``builds`` here."""
        return {f"s{sq}/kv{kv}": {
                    "source": m.source,
                    "plan": dict(m._plan_stats)}
                for (sq, kv), m in sorted(self._models.items())}
