"""Pure-jnp reference oracles for every Pallas kernel.

These are the *semantics* of the kernels: small, obviously-correct
implementations used (a) as the allclose oracle in the kernel test sweeps
and (b) as the CPU execution path of ``ops.py`` (interpret-mode Pallas is
far too slow for model-sized shapes).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Fused-epilogue activations (the Neutron activation engine, paper §III-B)
# --------------------------------------------------------------------------


def apply_activation(x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act in ("none", None):
        return x
    if act == "relu":
        return jax.nn.relu(x)
    if act == "relu6":
        return jnp.clip(x, 0, 6)
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    if act == "sqrelu":                       # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    if act == "mish":
        return x * jnp.tanh(jax.nn.softplus(x))
    raise ValueError(f"unknown activation {act!r}")


ACTIVATIONS = ("none", "relu", "relu6", "silu", "gelu", "sigmoid",
               "sqrelu", "mish")


# --------------------------------------------------------------------------
# neutron_matmul: output-stationary matmul + fused epilogue
# --------------------------------------------------------------------------


def neutron_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                       bias: Optional[jnp.ndarray] = None,
                       scale: Optional[jnp.ndarray] = None,
                       act: str = "none",
                       out_dtype: Optional[jnp.dtype] = None,
                       out_scale: Optional[float] = None) -> jnp.ndarray:
    """y = requant(act(scale * (x @ w) + bias)).

    int8 inputs accumulate in int32 (the engine's 32-bit accumulators);
    float inputs accumulate in float32.  `scale` is scalar or per-column.
    `out_scale` triggers int8 requantization of the result.
    """
    if x.dtype == jnp.int8:
        acc = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
    else:
        acc = jax.lax.dot_general(
            x.astype(jnp.float32), w.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    if scale is not None:
        acc = acc * scale
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    acc = apply_activation(acc, act)
    if out_scale is not None:
        q = jnp.round(acc / out_scale)
        return jnp.clip(q, -128, 127).astype(jnp.int8)
    return acc.astype(out_dtype or x.dtype
                      if x.dtype != jnp.int8 else jnp.float32)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------


def attention_naive(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    window: Optional[int] = None,
                    sm_scale: Optional[float] = None) -> jnp.ndarray:
    """Exact O(S^2) softmax attention.  q (B,H,S,D), k/v (B,H,S,D)."""
    B, H, S, D = q.shape
    sm_scale = sm_scale or 1.0 / math.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= qi - kj < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        window: Optional[int] = None,
                        sm_scale: Optional[float] = None,
                        block_k: int = 512) -> jnp.ndarray:
    """Streaming-softmax attention in jnp — O(S·block_k) memory.

    The memory-oracle for the Pallas flash kernel and the CPU/jit path
    used inside the LM models for long sequences.
    """
    B, H, S, D = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[2]
    sm_scale = sm_scale or 1.0 / math.sqrt(D)
    block_k = min(block_k, Sk)
    nk = math.ceil(Sk / block_k)
    pad = nk * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(B, H, nk, block_k, D)
    vb = v.reshape(B, H, nk, block_k, Dv)
    qf = q.astype(jnp.float32)
    qi = jnp.arange(S)[:, None]

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, j = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                       kc.astype(jnp.float32)) * sm_scale
        kj = j * block_k + jnp.arange(block_k)[None, :]
        mask = kj < Sk
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= qi - kj < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, H, S), dtype=jnp.float32)
    a0 = jnp.zeros((B, H, S, Dv), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kb.transpose(2, 0, 1, 3, 4), vb.transpose(2, 0, 1, 3, 4),
         jnp.arange(nk)))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def _flash_fwd_lse(q, k, v, causal, window, sm_scale, block_k):
    """Forward streaming softmax returning (o, lse).  Shapes as
    flash_attention_ref with H == Hkv."""
    B, H, S, D = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[2]
    block_k = min(block_k, Sk)
    nk = math.ceil(Sk / block_k)
    pad = nk * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(B, H, nk, block_k, D)
    vb = v.reshape(B, H, nk, block_k, Dv)
    qf = q.astype(jnp.float32)
    qi = jnp.arange(S)[:, None]

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, j = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                       kc.astype(jnp.float32)) * sm_scale
        kj = j * block_k + jnp.arange(block_k)[None, :]
        mask = kj < Sk
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= qi - kj < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, H, S), dtype=jnp.float32)
    a0 = jnp.zeros((B, H, S, Dv), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kb.transpose(2, 0, 1, 3, 4), vb.transpose(2, 0, 1, 3, 4),
         jnp.arange(nk)))
    l = jnp.maximum(l, 1e-30)
    o = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return o, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_fused(q, k, v, causal=True, window=None,
                          sm_scale=None, block_k=512):
    """Flash attention with a *fused backward*: residuals are only
    (q, k, v, o, lse) — O(S·D) — and the backward recomputes each score
    block (the standard FlashAttention-2 recipe).  Without this, autodiff
    of the forward scan stacks O(S²) per-block residuals, which the
    dry-run roofline exposes as a ~10x HBM-traffic bug (§Perf)."""
    sm = sm_scale or 1.0 / math.sqrt(q.shape[-1])
    o, _ = _flash_fwd_lse(q, k, v, causal, window, sm, block_k)
    return o


def _faf_fwd(q, k, v, causal, window, sm_scale, block_k):
    sm = sm_scale or 1.0 / math.sqrt(q.shape[-1])
    o, lse = _flash_fwd_lse(q, k, v, causal, window, sm, block_k)
    return o, (q, k, v, o, lse)


def _faf_bwd(causal, window, sm_scale, block_k, res, do):
    q, k, v, o, lse = res
    B, H, S, D = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[2]
    sm = sm_scale or 1.0 / math.sqrt(D)
    bk = min(block_k, Sk)
    nk = math.ceil(Sk / bk)
    pad = nk * bk - Sk
    kp, vp = k, v
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kp.reshape(B, H, nk, bk, D).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, H, nk, bk, Dv).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    delta = jnp.sum(dof * o.astype(jnp.float32), axis=-1)     # (B,H,S)
    qi = jnp.arange(S)[:, None]

    def step(dq, blk):
        kc, vc, j = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                       kc.astype(jnp.float32)) * sm
        kj = j * bk + jnp.arange(bk)[None, :]
        mask = kj < Sk
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= qi - kj < window
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)
        dv_j = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof,
                        vc.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * sm
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds,
                             kc.astype(jnp.float32))
        dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, H, S, D), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(step, dq0,
                                    (kb, vb, jnp.arange(nk)))
    dk = dk_b.transpose(1, 2, 0, 3, 4).reshape(B, H, nk * bk, D)[:, :, :Sk]
    dv = dv_b.transpose(1, 2, 0, 3, 4).reshape(B, H, nk * bk,
                                               Dv)[:, :, :Sk]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention_fused.defvjp(_faf_fwd, _faf_bwd)


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: Optional[jnp.ndarray] = None,
                     sm_scale: Optional[float] = None,
                     return_lse: bool = False):
    """Single-token decode attention.  q (B,H,D); k/v (B,H,S,D).

    `kv_len` (B,) masks the valid prefix of the cache.  With
    ``return_lse`` the (B,H) log-sum-exp is returned for cross-shard
    combination (long-context KV sharded over devices).
    """
    B, H, S, D = k.shape
    sm_scale = sm_scale or 1.0 / math.sqrt(D)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if kv_len is not None:
        mask = jnp.arange(S)[None, None, :] < kv_len[:, None, None]
        s = jnp.where(mask, s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1)
    o = jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32))
    o = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    if return_lse:
        lse = m[..., 0] + jnp.log(jnp.maximum(l, 1e-30))
        return o, lse
    return o


def combine_decode_shards(outs: jnp.ndarray, lses: jnp.ndarray
                          ) -> jnp.ndarray:
    """Merge per-shard decode partials.  outs (N,B,H,D), lses (N,B,H)."""
    m = lses.max(axis=0)
    w = jnp.exp(lses - m)                      # (N,B,H)
    denom = w.sum(axis=0)
    o = (outs.astype(jnp.float32) * w[..., None]).sum(axis=0)
    return (o / jnp.maximum(denom, 1e-30)[..., None]).astype(outs.dtype)


# --------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) chunked scan
# --------------------------------------------------------------------------


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray,
                 chunk: int = 64,
                 init_state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD forward (Mamba2, arXiv:2405.21060 §6).

    x  (B, S, H, P)   per-head inputs
    dt (B, S, H)      softplus-activated step sizes (>0)
    A  (H,)           negative decay rates
    Bm (B, S, N)      input projection (single group)
    Cm (B, S, N)      output projection
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = math.ceil(S / chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    xc = x.reshape(Bsz, nc, L, H, P)
    dtc = dt.reshape(Bsz, nc, L, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, L, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, L, N).astype(jnp.float32)

    da = dtc * A.astype(jnp.float32)[None, None, None, :]   # (B,nc,L,H)
    seg = jnp.cumsum(da, axis=2)                            # inclusive
    # intra-chunk: y[t] = sum_{s<=t} C[t]·B[s] exp(seg[t]-seg[s]) dt[s] x[s]
    cb = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)              # (B,nc,L,L)
    decay = seg[:, :, :, None, :] - seg[:, :, None, :, :]   # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), dtype=bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, -jnp.inf)
    gate = jnp.exp(decay)                                   # (B,nc,L,L,H)
    y_in = jnp.einsum("bclm,bclmh,bcmh,bcmhp->bclhp",
                      cb, gate, dtc, xc.astype(jnp.float32))
    # chunk state contribution: sum_s exp(seg[L-1]-seg[s]) dt[s] B[s]⊗x[s]
    tail = jnp.exp(seg[:, :, -1:, :] - seg)                 # (B,nc,L,H)
    contrib = jnp.einsum("bclh,bclh,bcln,bclhp->bchpn",
                         tail, dtc, Bc, xc.astype(jnp.float32))
    total = jnp.exp(seg[:, :, -1, :])                       # (B,nc,H)

    def scan_state(s_prev, inp):
        contrib_c, total_c = inp
        s_new = s_prev * total_c[..., None, None] + contrib_c
        return s_new, s_prev

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bsz, H, P, N), dtype=jnp.float32))
    s_final, s_prevs = jax.lax.scan(
        scan_state, s0,
        (contrib.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)              # (B,nc,H,P,N)
    # inter-chunk: y[t] += C[t] · (exp(seg[t]) * S_prev)
    y_out = jnp.einsum("bcln,bclh,bchpn->bclhp",
                       Cc, jnp.exp(seg), s_prevs)
    y = (y_in + y_out).reshape(Bsz, nc * L, H, P)[:, :S]
    return y.astype(x.dtype), s_final.astype(x.dtype)


def ssd_step_ref(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                 A: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSD recurrence (decode).  state (B,H,P,N);
    x (B,H,P); dt (B,H); Bm/Cm (B,N)."""
    da = jnp.exp(dt.astype(jnp.float32) *
                 A.astype(jnp.float32)[None, :])            # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(jnp.float32),
                     Bm.astype(jnp.float32), x.astype(jnp.float32))
    new = state.astype(jnp.float32) * da[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), new)
    return y.astype(x.dtype), new.astype(state.dtype)
