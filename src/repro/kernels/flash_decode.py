"""Flash-decode Pallas kernel — one new token against a long KV cache.

Decode attention is *memory-roofline* work (arithmetic intensity ~2
ops/byte over the KV cache); the kernel's only job is to stream the cache
through VMEM exactly once at full bandwidth with streaming softmax — the
Neutron "one operand stays stationary (q), the other streams (KV)"
pattern.  Optionally emits the per-(batch, head) log-sum-exp so that
partial results computed on different devices (KV sharded along sequence
for 500k-token contexts) combine exactly.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                   m_ref, l_ref, acc_ref, *,
                   sm_scale: float, block_k: int, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = pl.program_id(0)
    kv_len = len_ref[b]
    k0 = ik * block_k

    @pl.when(k0 < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kj = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s * sm_scale, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l)).astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "block_k", "return_lse", "interpret"))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 kv_len: Optional[jnp.ndarray] = None,
                 sm_scale: Optional[float] = None,
                 block_k: int = 256, return_lse: bool = False,
                 interpret: bool = True):
    """q (B,H,D); k (B,Hkv,S,D); v (B,Hkv,S,Dv); kv_len (B,)."""
    B, H, D = q.shape
    _, Hkv, S, _ = k.shape
    Dv = v.shape[-1]
    assert H % Hkv == 0
    group = H // Hkv
    sm_scale = sm_scale or 1.0 / math.sqrt(D)
    if kv_len is None:
        kv_len = jnp.full((B,), S, dtype=jnp.int32)

    bk = min(block_k, S)
    Sp = math.ceil(S / bk) * bk
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    n_k = Sp // bk
    grid = (B, H, n_k)

    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               block_k=bk, n_k=n_k)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # kv_len (B,)
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv),
                         lambda b, h, j: (b, h // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Dv), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, 1, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, H, 1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, Dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q.reshape(B, H, 1, D), k, v)
    o = out.reshape(B, H, Dv)
    if return_lse:
        return o, lse.reshape(B, H)
    return o
