"""Pallas-TPU API compatibility shims.

``pltpu.CompilerParams`` is the current spelling; older jax releases
ship the same dataclass as ``pltpu.TPUCompilerParams``.  Import
``CompilerParams`` from here so the kernels build against both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
