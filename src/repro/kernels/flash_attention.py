"""Flash attention (prefill) Pallas kernel — causal / sliding-window / GQA.

The TPU-native instance of the paper's data-movement thesis for the
attention hot-spot: softmax statistics (m, l) and the output accumulator
stay *output-stationary* in VMEM while KV blocks stream through the grid
pipeline; no (S x S) score matrix ever exists in HBM.

GQA is handled in the BlockSpec index maps (q head h reads kv head
h // group) — the shared-operand trick of the Neutron bus (one KV operand
feeds `group` query heads).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sm_scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, n_k: int, kv_len: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    iq = pl.program_id(2)
    q0 = iq * block_q
    k0 = ik * block_k

    run = jnp.asarray(True)
    if causal:
        # skip fully-masked blocks (upper triangle)
        run = jnp.logical_and(run, k0 <= q0 + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, q0 - (k0 + block_k - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        qi = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kj = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kj < kv_len
        if causal:
            mask &= kj <= qi
        if window is not None:
            mask &= qi - kj < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    sm_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q (B,H,S,D); k (B,Hkv,Sk,D); v (B,Hkv,Sk,Dv); H % Hkv == 0."""
    B, H, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    Dv = v.shape[-1]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    sm_scale = sm_scale or 1.0 / math.sqrt(D)

    bq = min(block_q, S)
    bk = min(block_k, Sk)
    Sp = math.ceil(S / bq) * bq
    Skp = math.ceil(Sk / bk) * bk
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    n_q = Sp // bq
    n_k = Skp // bk
    grid = (B, H, n_q, n_k)

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_k=n_k, kv_len=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running sum
            pltpu.VMEM((bq, Dv), jnp.float32),    # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
