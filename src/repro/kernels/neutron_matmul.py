"""Output-stationary fused matmul — the Neutron dot-product engine on TPU.

TPU-native adaptation of paper §III-B:

  * the engine's wide 32-bit accumulators -> a VMEM f32/i32 accumulator
    scratch that never leaves the core while K streams through
    (*output-stationary*, "completely avoid outside memory accesses for
    wide 32-bit accumulator values");
  * the A-deep accumulator pool / operand sharing -> (block_m x block_n)
    MXU-aligned output blocks reusing both operand blocks block_k times;
  * the fused rescale -> activation epilogue ("activation engine") runs on
    the accumulator before the single result write-back, including the
    int8 requantization path of the INT8 deployment;
  * the data-engine prefetcher -> the Pallas grid pipeline double-buffers
    HBM->VMEM block copies automatically.

Block shapes are multiples of (8, 128) sublane/lane tiles; defaults
(128, 128, 512) keep the working set (x-blk + w-blk + acc ≈ 192 KiB bf16)
far under the ~16 MiB VMEM while saturating the 128x128 MXU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

from .ref import apply_activation


def _matmul_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
                   act: str, n_k: int, requant: bool,
                   out_scale: Optional[float]):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    if x.dtype == jnp.int8:
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        if scale_ref is not None:
            acc = acc * scale_ref[...].astype(jnp.float32)
        if bias_ref is not None:
            acc = acc + bias_ref[...].astype(jnp.float32)
        acc = apply_activation(acc, act)
        if requant:
            q = jnp.round(acc / out_scale)
            o_ref[...] = jnp.clip(q, -128, 127).astype(o_ref.dtype)
        else:
            o_ref[...] = acc.astype(o_ref.dtype)


def _pad_to(a: jnp.ndarray, mults) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(a.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(a, pads)
    return a


@functools.partial(
    jax.jit,
    static_argnames=("act", "out_dtype", "out_scale", "block_m", "block_n",
                     "block_k", "interpret"))
def neutron_matmul(x: jnp.ndarray, w: jnp.ndarray,
                   bias: Optional[jnp.ndarray] = None,
                   scale: Optional[jnp.ndarray] = None,
                   act: str = "none",
                   out_dtype: Optional[jnp.dtype] = None,
                   out_scale: Optional[float] = None,
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 512,
                   interpret: bool = True) -> jnp.ndarray:
    """y[M,N] = requant(act(scale * (x[M,K] @ w[K,N]) + bias))."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    requant = out_scale is not None
    if out_dtype is None:
        out_dtype = jnp.int8 if requant else (
            jnp.float32 if x.dtype == jnp.int8 else x.dtype)

    bm = min(block_m, max(8, M))
    bn = min(block_n, max(128, N))
    bk = min(block_k, max(128, K))
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    Mp, Kp = xp.shape
    Np = wp.shape[1]
    n_k = Kp // bk
    grid = (Mp // bm, Np // bn, n_k)

    acc_dtype = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [xp, wp]
    if scale is not None:
        sc = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (N,))
        args.append(_pad_to(sc.reshape(1, N), (1, bn)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
    if bias is not None:
        args.append(_pad_to(bias.reshape(1, N), (1, bn)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))

    def kernel(*refs):
        x_ref, w_ref = refs[0], refs[1]
        idx = 2
        scale_ref = bias_ref = None
        if scale is not None:
            scale_ref = refs[idx]
            idx += 1
        if bias is not None:
            bias_ref = refs[idx]
            idx += 1
        o_ref, acc_ref = refs[-2], refs[-1]
        _matmul_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref,
                       act=act, n_k=n_k, requant=requant,
                       out_scale=out_scale)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    return out[:M, :N]
