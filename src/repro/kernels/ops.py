"""Public kernel ops — implementation dispatch.

Every op has two implementations with identical semantics:

  * ``pallas``  — the TPU-target kernel (``interpret=True`` on CPU, so it
    runs the kernel body in Python; correct but slow);
  * ``ref``     — the pure-jnp oracle (fast under jit on CPU, and what the
    models use when not running on TPU).

``impl="auto"`` picks pallas on TPU and ref elsewhere, so the same model
code is TPU-native in production and CPU-testable here.  Tests pin
``impl="pallas"`` (interpret) vs ``impl="ref"`` and assert allclose.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as _ref
from .flash_attention import flash_attention as _flash_attention_pallas
from .flash_decode import flash_decode as _flash_decode_pallas
from .neutron_matmul import neutron_matmul as _neutron_matmul_pallas
from .ssd_scan import ssd_chunk as _ssd_chunk_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# --------------------------------------------------------------------------
# neutron_matmul
# --------------------------------------------------------------------------


def neutron_matmul(x, w, bias=None, scale=None, act: str = "none",
                   out_dtype=None, out_scale: Optional[float] = None,
                   impl: str = "auto", **block_kw):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.neutron_matmul_ref(x, w, bias=bias, scale=scale,
                                       act=act, out_dtype=out_dtype,
                                       out_scale=out_scale)
    interpret = not _on_tpu()
    return _neutron_matmul_pallas(x, w, bias=bias, scale=scale, act=act,
                                  out_dtype=out_dtype, out_scale=out_scale,
                                  interpret=interpret, **block_kw)


# --------------------------------------------------------------------------
# flash attention (prefill / train)
# --------------------------------------------------------------------------


def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    sm_scale: Optional[float] = None,
                    impl: str = "auto", fused_vjp: bool = True,
                    **block_kw):
    """q (B,H,S,D); k/v (B,Hkv,Sk,D).

    ``fused_vjp`` uses the FlashAttention-2-style custom backward
    (O(S·D) residuals).  ``fused_vjp=False`` differentiates through the
    forward scan — the naive baseline that stacks O(S²) residuals,
    kept selectable for the §Perf before/after measurement."""
    impl = _resolve(impl)
    if impl == "ref":
        H, Hkv = q.shape[1], k.shape[1]
        if H != Hkv:
            g = H // Hkv
            k = jnp.repeat(k, g, axis=1)
            v = jnp.repeat(v, g, axis=1)
        if fused_vjp:
            return _ref.flash_attention_fused(
                q, k, v, causal, window, sm_scale,
                block_kw.get("block_k", 512))
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window, sm_scale=sm_scale)
    interpret = not _on_tpu()
    return _flash_attention_pallas(q, k, v, causal=causal, window=window,
                                   sm_scale=sm_scale, interpret=interpret,
                                   **block_kw)


# --------------------------------------------------------------------------
# flash decode
# --------------------------------------------------------------------------


def flash_decode(q, k, v, kv_len=None, sm_scale: Optional[float] = None,
                 return_lse: bool = False, impl: str = "auto", **block_kw):
    """q (B,H,D); k/v (B,Hkv,S,D)."""
    impl = _resolve(impl)
    if impl == "ref":
        H, Hkv = q.shape[1], k.shape[1]
        if H != Hkv:
            g = H // Hkv
            k = jnp.repeat(k, g, axis=1)
            v = jnp.repeat(v, g, axis=1)
        return _ref.flash_decode_ref(q, k, v, kv_len=kv_len,
                                     sm_scale=sm_scale,
                                     return_lse=return_lse)
    interpret = not _on_tpu()
    return _flash_decode_pallas(q, k, v, kv_len=kv_len, sm_scale=sm_scale,
                                return_lse=return_lse, interpret=interpret,
                                **block_kw)


combine_decode_shards = _ref.combine_decode_shards


# --------------------------------------------------------------------------
# Mamba2 SSD scan
# --------------------------------------------------------------------------


def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 64, init_state=None,
             impl: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full chunked SSD: intra-chunk kernel + cross-chunk jnp recurrence.

    x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk,
                                 init_state=init_state)
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = math.ceil(S / chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    interpret = not _on_tpu()
    y_in, contrib, total, seg = _ssd_chunk_pallas(
        x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)

    def scan_state(s_prev, inp):
        contrib_c, total_c = inp
        return s_prev * total_c[..., None, None] + contrib_c, s_prev

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bsz, H, P, N), dtype=jnp.float32))
    s_final, s_prevs = jax.lax.scan(
        scan_state, s0,
        (contrib.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)            # (B,nc,H,P,N)
    L = chunk
    segc = seg.reshape(Bsz, nc, L, H)
    Cc = Cm.reshape(Bsz, nc, L, N).astype(jnp.float32)
    y_out = jnp.einsum("bcln,bclh,bchpn->bclhp", Cc, jnp.exp(segc),
                       s_prevs)
    y = (y_in.reshape(Bsz, nc, L, H, P) +
         y_out).reshape(Bsz, nc * L, H, P)[:, :S]
    return y.astype(x.dtype), s_final.astype(x.dtype)


ssd_step = _ref.ssd_step_ref          # O(1) decode step (pure jnp)
apply_activation = _ref.apply_activation
