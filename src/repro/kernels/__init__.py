"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as (pallas kernel, jit'd wrapper in ops.py, pure-jnp
oracle in ref.py); see ops.py for the dispatch contract.
"""
from .ops import (apply_activation, combine_decode_shards, flash_attention,
                  flash_decode, neutron_matmul, ssd_scan, ssd_step)

__all__ = [
    "neutron_matmul", "flash_attention", "flash_decode",
    "combine_decode_shards", "ssd_scan", "ssd_step", "apply_activation",
]
