"""Mamba2 SSD chunk kernel (state-space duality, arXiv:2405.21060 §6).

The SSD algorithm splits the sequence into chunks: within a chunk the
recurrence is computed as a (masked, decay-weighted) attention-like
quadratic form — MXU-friendly matmuls — while an O(S/L) recurrence
carries state across chunks.  This kernel computes the *intra-chunk*
quadratic part plus each chunk's state contribution and total decay; the
cheap cross-chunk scan runs in jnp (``ops.ssd_scan``).

The mapping to the paper's architecture: the (L x L) decay-gated score
block and the (P x N) state contribution live in VMEM for the duration of
a chunk (output-stationary), while x/dt/B/C chunk operands stream in —
exactly the operand-bandwidth-vs-accumulator-locality trade the Neutron
dot-product engine makes with its A-deep accumulator pool.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, contrib_ref, total_ref, seg_ref, *,
                      chunk: int):
    """Grid cell = (batch, chunk, head).  Blocks:
    x (L,P), dt (L,1), a (1,1), b (L,N), c (L,N) ->
    y_intra (L,P), contrib (P,N), total (1,1), seg (L,1)."""
    x = x_ref[0, 0].astype(jnp.float32)           # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (L, 1)
    A = a_ref[0, 0]                               # scalar decay rate (<0)
    Bm = b_ref[0, 0].astype(jnp.float32)          # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)          # (L, N)

    da = dt * A                                   # (L, 1)
    seg = jnp.cumsum(da, axis=0)                  # inclusive cumsum (L, 1)
    # decay-gated scores: G[t,s] = exp(seg[t]-seg[s]) * (C[t]·B[s]) * dt[s]
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    decay = seg - seg.reshape(1, chunk)           # seg[t] - seg[s]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gate = jnp.where(si <= ti, jnp.exp(decay), 0.0)
    scores = cb * gate * dt.reshape(1, chunk)     # (L, L)
    y_ref[0, 0] = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)
    # chunk state contribution: sum_s exp(seg[-1]-seg[s]) dt[s] x[s]⊗B[s]
    tail = jnp.exp(seg[chunk - 1] - seg) * dt     # (L, 1)
    xw = x * tail                                 # (L, P)
    contrib_ref[0, 0] = jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(contrib_ref.dtype)
    total_ref[0, 0] = jnp.exp(seg[chunk - 1:chunk]).astype(total_ref.dtype)
    seg_ref[0, 0] = seg.astype(seg_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
              Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int = 64,
              interpret: bool = True
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                         jnp.ndarray]:
    """Intra-chunk SSD.  x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).
    S must be a multiple of `chunk` (ops.py pads).

    Returns (y_intra (B,S,H,P), contrib (B,nc,H,P,N), total (B,nc,H),
    seg (B,S,H))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    L = chunk

    # layout: (B, nc, H, L, ...) so each grid cell reads one (L, ...) block
    xr = x.reshape(Bsz, nc, L, H, P).transpose(0, 1, 3, 2, 4)
    dtr = dt.reshape(Bsz, nc, L, H).transpose(0, 1, 3, 2)[..., None]
    br = jnp.broadcast_to(Bm.reshape(Bsz, nc, 1, L, N),
                          (Bsz, nc, H, L, N))
    cr = jnp.broadcast_to(Cm.reshape(Bsz, nc, 1, L, N),
                          (Bsz, nc, H, L, N))
    ar = A.reshape(H, 1).astype(jnp.float32)

    grid = (Bsz * nc, H)
    kernel = functools.partial(_ssd_chunk_kernel, chunk=L)
    y, contrib, total, seg = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1), lambda bc, h: (h, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda bc, h: (bc, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda bc, h: (bc, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz * nc, H, L, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz * nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((Bsz * nc, H, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((Bsz * nc, H, L, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xr.reshape(Bsz * nc, H, L, P), dtr.reshape(Bsz * nc, H, L, 1),
      ar, br.reshape(Bsz * nc, H, L, N), cr.reshape(Bsz * nc, H, L, N))

    y = y.reshape(Bsz, nc, H, L, P).transpose(0, 1, 3, 2, 4) \
         .reshape(Bsz, S, H, P)
    contrib = contrib.reshape(Bsz, nc, H, P, N)
    total = total.reshape(Bsz, nc, H)
    seg = seg.reshape(Bsz, nc, H, L).transpose(0, 1, 3, 2) \
             .reshape(Bsz, S, H)
    return y, contrib, total, seg
