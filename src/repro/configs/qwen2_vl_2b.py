"""qwen2-vl-2b — M-RoPE decoder backbone [arXiv:2409.12191].

The ViT frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings merged into the token stream; M-RoPE uses
sections (16, 24, 24) over head_dim/2 = 64.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936,
    mrope=True, mrope_sections=(16, 24, 24), n_vision_tokens=256,
    act="silu", gated_mlp=True, tie_embeddings=True,
    tp_pad=16,
)
