"""granite-3.0-1b-a400m — 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155,
    n_experts=32, top_k=8, moe_d_ff=512,
    act="silu", gated_mlp=True, tie_embeddings=True,
    tp_pad=16,
)
