"""granite-20b-code — MQA (kv=1) GPT-BigCode-style code model
[arXiv:2405.04324].  The single KV head is replicated across the model
axis (the paper's broadcast-operand case)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152,
    act="gelu", gated_mlp=False,
    tp_pad=16,
)
