"""zamba2-2.7b — Mamba2 backbone + shared attention block (hybrid).

54 SSD layers; one *shared* full-attention transformer block applied
every 6 layers with per-invocation LoRA deltas [arXiv:2411.15242].
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    shared_attn_every=6, lora_rank=128,
    act="silu", gated_mlp=True,
    tp_pad=16,
)
