"""deepseek-v3-671b — MLA + 1 shared + 256 routed top-8 experts + MTP
[arXiv:2412.19437].

Assignment d_ff=2048 is the routed-expert hidden dim; the 3 dense
warm-up layers use the paper's 18432 FFN.  FSDP sharding over the data
axis is required to fit 671B on 256/512 v5e chips.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280,
    n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    moe_layer_start=3,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    d_nope=128, d_rope=64, d_v=128, mtp=True,
    act="silu", gated_mlp=True, fsdp=True,
    tp_pad=16,
)
