"""gemma3-27b — 5:1 local:global attention, 1024-token sliding window,
128k+ context [hf:google/gemma-3-*].  head_dim pinned at 128."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262144, d_head=128,
    local_global_ratio=5, sliding_window=1024,
    act="gelu", gated_mlp=True, tie_embeddings=True,
    tp_pad=16,
)
