"""whisper-tiny — encoder-decoder ASR backbone [arXiv:2212.04356].

The conv/log-mel frontend is a STUB per the assignment: input_specs()
provides precomputed frame embeddings (B, 1500, 384).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    enc_dec=True, n_enc_layers=4, n_audio_frames=1500,
    act="gelu", gated_mlp=False, tie_embeddings=True,
    tp_pad=16,
)
