"""Per-architecture configs (assigned pool) + the paper's vision suite."""
ARCH_MODULES = [
    "zamba2_2_7b", "whisper_tiny", "granite_moe_1b_a400m",
    "deepseek_v3_671b", "mamba2_370m", "minitron_4b", "gemma3_27b",
    "nemotron_4_340b", "granite_20b", "qwen2_vl_2b",
]
