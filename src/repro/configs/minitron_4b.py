"""minitron-4b — width/depth-pruned Nemotron-4 [arXiv:2407.14679].
Squared-ReLU non-gated MLP per the Nemotron lineage."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab=256000,
    act="sqrelu", gated_mlp=False,
    tp_pad=16,
)
