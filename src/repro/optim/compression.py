"""Error-feedback int8 gradient compression for the cross-pod axis.

At 2+ pods the gradient all-reduce crosses DCN (much slower than ICI).
Compressing the cross-pod payload to int8 with per-tensor scales cuts
those bytes 4x (bf16) while error feedback keeps the optimizer unbiased:
the quantization residual is carried to the next step — standard
EF-SGD/EF21-style memory.

Usage inside train_step (per parameter leaf):
    q, scale, new_err = compress(g + err)
    g_hat = decompress(q, scale)              # what actually syncs
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_leaf(g: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """g -> (int8 q, scale, residual)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    resid = gf - q.astype(jnp.float32) * scale
    return q, scale, resid


def decompress_leaf(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Returns (decompressed grads as synced, new error feedback)."""

    def one(g, e):
        q, s, r = compress_leaf(g.astype(jnp.float32) + e)
        return decompress_leaf(q, s).astype(g.dtype), r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    gs = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    es = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return gs, es
