"""AdamW with sharding-aware state and selectable moment precision.

Optimizer state mirrors the parameter tree (so the parameter
PartitionSpecs apply verbatim — FSDP sharding of m/v comes for free).
``moment_dtype="bfloat16"`` halves optimizer memory for the 340B/671B
configs (the fit-or-not call in the dry-run memory analysis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"       # "bfloat16" for giant configs


def init_state(cfg: AdamWConfig, params: Any) -> AdamWState:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def z(p):
        return jnp.zeros(p.shape, dt)

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(z, params),
                      v=jax.tree_util.tree_map(z, params))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: AdamWState,
                  lr_scale: jnp.ndarray | float = 1.0
                  ) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) \
        if cfg.grad_clip else 1.0

    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m2 / b1t
        vh = v2 / b2t
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
