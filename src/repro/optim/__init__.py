from .adamw import AdamWConfig, AdamWState, apply_updates, global_norm, \
    init_state
from .compression import compress_grads, init_error
from .schedules import constant, warmup_cosine

__all__ = ["AdamWConfig", "AdamWState", "apply_updates", "global_norm",
           "init_state", "compress_grads", "init_error", "constant",
           "warmup_cosine"]
