"""Vision-model frontends — the paper's benchmark suite (Table IV).

Builds every model of paper §V as a :class:`repro.core.ir.Graph`:
MobileNetV1/V2/V3-minimalistic, ResNet50V1, EfficientNet-Lite0,
EfficientDet-Lite0, YOLOv8n (det + seg), YOLOv8s, MobileNetV1/V2-SSD and a
DAMO-YOLO-NL-class model.  BatchNorm is folded into the convolutions
(the INT8 deployment the paper measures).  MAC counts are validated
against Table IV in ``tests/test_vision.py``.

``build(name, res_scale=1.0)`` returns ``(graph, builder)``; res_scale
shrinks the input resolution for fast functional tests (the topology and
channel counts are unchanged).  Built graphs are memoized per
``(name, resolution)`` — repeated builder calls (benchmarks, serving
compiles, quantize-then-compare flows) get a cheap structural clone
instead of re-deriving every shape (~10% of a cache-miss compile on the
YOLO-class models).  ``build_quantized`` runs the int8/int4 PTQ flow of
:mod:`repro.quant` over a built graph with synthetic calibration data.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.ir import Graph, GraphBuilder, Op, Tensor

# --------------------------------------------------------------------------
# Shared blocks
# --------------------------------------------------------------------------


def _dw_sep(b: GraphBuilder, x: str, out_c: int, s: int = 1,
            act: str = "relu6", k: int = 3) -> str:
    """Depthwise-separable conv (MobileNetV1 block)."""
    x = b.dwconv(x, k=k, s=s, act=act)
    return b.conv(x, out_c, k=1, act=act)


def _inv_res(b: GraphBuilder, x: str, exp: int, out_c: int, s: int = 1,
             k: int = 3, act: str = "relu6") -> str:
    """MobileNetV2 inverted residual (expand -> dw -> project-linear)."""
    in_c = b.g.tensors[x].hwc[2]
    h = x
    if exp != in_c:
        h = b.conv(h, exp, k=1, act=act)
    h = b.dwconv(h, k=k, s=s, act=act)
    h = b.conv(h, out_c, k=1, act="none")
    if s == 1 and in_c == out_c:
        h = b.add(x, h)
    return h


def _res_bottleneck(b: GraphBuilder, x: str, c: int, s: int = 1,
                    first: bool = False) -> str:
    """ResNet50V1 bottleneck: 1x1(c, stride s) -> 3x3(c) -> 1x1(4c)."""
    in_c = b.g.tensors[x].hwc[2]
    h = b.conv(x, c, k=1, s=s, act="relu")        # v1: stride on first 1x1
    h = b.conv(h, c, k=3, s=1, act="relu")
    h = b.conv(h, 4 * c, k=1, act="none")
    if first or s != 1 or in_c != 4 * c:
        sc = b.conv(x, 4 * c, k=1, s=s, act="none")
    else:
        sc = x
    return b.add(h, sc, act="relu")


def _cbs(b: GraphBuilder, x: str, c: int, k: int = 3, s: int = 1) -> str:
    """YOLOv8 Conv-BN-SiLU."""
    return b.conv(x, c, k=k, s=s, act="silu")


def _c2f(b: GraphBuilder, x: str, c: int, n: int,
         shortcut: bool = True) -> str:
    """YOLOv8 C2f: split + n bottlenecks + concat + 1x1 fuse."""
    h = c // 2
    y = _cbs(b, x, 2 * h, k=1)
    parts = b.split(y, 2)
    feats = [parts[0], parts[1]]
    cur = parts[1]
    for _ in range(n):
        z = _cbs(b, cur, h, k=3)
        z = _cbs(b, z, h, k=3)
        cur = b.add(cur, z) if shortcut else z
        feats.append(cur)
    return _cbs(b, b.concat(feats), c, k=1)


def _sppf(b: GraphBuilder, x: str, c: int) -> str:
    h = c // 2
    y = _cbs(b, x, h, k=1)
    p1 = b.maxpool(y, k=5, s=1, pad="same")
    p2 = b.maxpool(p1, k=5, s=1, pad="same")
    p3 = b.maxpool(p2, k=5, s=1, pad="same")
    return _cbs(b, b.concat([y, p1, p2, p3]), c, k=1)


# --------------------------------------------------------------------------
# Classification models
# --------------------------------------------------------------------------


def mobilenet_v1(res: int = 224) -> Tuple[Graph, GraphBuilder]:
    b = GraphBuilder("mobilenet_v1")
    x = b.input((res, res, 3))
    x = b.conv(x, 32, k=3, s=2, act="relu6")
    cfg = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
           (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
           (1024, 1)]
    for c, s in cfg:
        x = _dw_sep(b, x, c, s=s)
    x = b.global_avgpool(x)
    x = b.fc(x, 1000)
    b.mark_output(x)
    return b.build(), b


def mobilenet_v2(res: int = 224) -> Tuple[Graph, GraphBuilder]:
    b = GraphBuilder("mobilenet_v2")
    x = b.input((res, res, 3))
    x = b.conv(x, 32, k=3, s=2, act="relu6")
    # (t, c, n, s)
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for t, c, n, s in cfg:
        for i in range(n):
            in_c = b.g.tensors[x].hwc[2]
            x = _inv_res(b, x, exp=in_c * t, out_c=c, s=s if i == 0 else 1)
    x = b.conv(x, 1280, k=1, act="relu6")
    x = b.global_avgpool(x)
    x = b.fc(x, 1000)
    b.mark_output(x)
    return b.build(), b


def mobilenet_v3_min(res: int = 224) -> Tuple[Graph, GraphBuilder]:
    """MobileNetV3-Large *minimalistic*: no SE, no h-swish, 3x3 only."""
    b = GraphBuilder("mobilenet_v3_min")
    x = b.input((res, res, 3))
    x = b.conv(x, 16, k=3, s=2, act="relu")
    # (exp, out, s) — large config with k=3/RE/no-SE (minimalistic)
    cfg = [(16, 16, 1), (64, 24, 2), (72, 24, 1), (72, 40, 2), (120, 40, 1),
           (120, 40, 1), (240, 80, 2), (200, 80, 1), (184, 80, 1),
           (184, 80, 1), (480, 112, 1), (672, 112, 1), (672, 160, 2),
           (960, 160, 1), (960, 160, 1)]
    for exp, c, s in cfg:
        x = _inv_res(b, x, exp=exp, out_c=c, s=s, act="relu")
    x = b.conv(x, 960, k=1, act="relu")
    x = b.global_avgpool(x)
    x = b.conv(x, 1280, k=1, act="relu")
    x = b.fc(x, 1000)
    b.mark_output(x)
    return b.build(), b


def resnet50_v1(res: int = 224) -> Tuple[Graph, GraphBuilder]:
    b = GraphBuilder("resnet50_v1")
    x = b.input((res, res, 3))
    x = b.conv(x, 64, k=7, s=2, act="relu")
    x = b.maxpool(x, k=3, s=2, pad="same")
    for stage, (c, n) in enumerate([(64, 3), (128, 4), (256, 6), (512, 3)]):
        for i in range(n):
            s = 2 if (i == 0 and stage > 0) else 1
            x = _res_bottleneck(b, x, c, s=s, first=(i == 0))
    x = b.global_avgpool(x)
    x = b.fc(x, 1000)
    b.mark_output(x)
    return b.build(), b


def efficientnet_lite0(res: int = 224) -> Tuple[Graph, GraphBuilder]:
    b = GraphBuilder("efficientnet_lite0")
    x = b.input((res, res, 3))
    x = b.conv(x, 32, k=3, s=2, act="relu6")
    # (t, k, c, n, s) — lite0: no SE, relu6
    cfg = [(1, 3, 16, 1, 1), (6, 3, 24, 2, 2), (6, 5, 40, 2, 2),
           (6, 3, 80, 3, 2), (6, 5, 112, 3, 1), (6, 5, 192, 4, 2),
           (6, 3, 320, 1, 1)]
    for t, k, c, n, s in cfg:
        for i in range(n):
            in_c = b.g.tensors[x].hwc[2]
            x = _inv_res(b, x, exp=in_c * t, out_c=c,
                         s=s if i == 0 else 1, k=k)
    x = b.conv(x, 1280, k=1, act="relu6")
    x = b.global_avgpool(x)
    x = b.fc(x, 1000)
    b.mark_output(x)
    return b.build(), b


# --------------------------------------------------------------------------
# SSD detectors
# --------------------------------------------------------------------------


def _ssd_heads(b: GraphBuilder, feats: List[str], anchors: List[int],
               n_classes: int = 91, lite: bool = False) -> List[str]:
    """1x1 box predictors (the TF-OD 'reduced' BoxPredictor used by the
    deployed TFLite SSD models); SSDLite uses dw-separable 3x3 heads."""
    outs = []
    for f, a in zip(feats, anchors):
        if lite:
            loc = b.dwconv(f, k=3, act="relu6")
            loc = b.conv(loc, a * 4, k=1)
            cls = b.dwconv(f, k=3, act="relu6")
            cls = b.conv(cls, a * n_classes, k=1)
        else:
            loc = b.conv(f, a * 4, k=1)
            cls = b.conv(f, a * n_classes, k=1)
        outs += [b.mark_output(loc), b.mark_output(cls)]
    return outs


def mobilenet_v1_ssd(res: int = 300) -> Tuple[Graph, GraphBuilder]:
    b = GraphBuilder("mobilenet_v1_ssd")
    x = b.input((res, res, 3))
    x = b.conv(x, 32, k=3, s=2, act="relu6")
    cfg = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
           (512, 1), (512, 1), (512, 1), (512, 1), (512, 1)]
    feats = []
    for c, s in cfg:
        x = _dw_sep(b, x, c, s=s)
    feats.append(x)                                   # 19x19x512
    x = _dw_sep(b, x, 1024, s=2)
    x = _dw_sep(b, x, 1024, s=1)
    feats.append(x)                                   # 10x10x1024
    for c in (256, 256, 128, 128):                    # extra feature layers
        x = b.conv(x, c // 2, k=1, act="relu6")
        x = b.conv(x, c, k=3, s=2, act="relu6")
        feats.append(x)
    _ssd_heads(b, feats, anchors=[3, 6, 6, 6, 6, 6])
    return b.build(), b


def mobilenet_v2_ssd(res: int = 300) -> Tuple[Graph, GraphBuilder]:
    """MobileNetV2 + SSDLite (dw-separable heads and extras)."""
    b = GraphBuilder("mobilenet_v2_ssd")
    x = b.input((res, res, 3))
    x = b.conv(x, 32, k=3, s=2, act="relu6")
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1)]
    feats = []
    for t, c, n, s in cfg:
        for i in range(n):
            in_c = b.g.tensors[x].hwc[2]
            x = _inv_res(b, x, exp=in_c * t, out_c=c, s=s if i == 0 else 1)
    # expansion of the first 160-block is SSD feature 1 (19x19x576)
    f1 = b.conv(x, 576, k=1, act="relu6")
    feats.append(f1)
    h = b.dwconv(f1, k=3, s=2, act="relu6")
    x = b.conv(h, 160, k=1, act="none")
    for i in range(2):
        x = _inv_res(b, x, exp=960, out_c=160, s=1)
    x = _inv_res(b, x, exp=960, out_c=320, s=1)
    x = b.conv(x, 1280, k=1, act="relu6")
    feats.append(x)                                   # 10x10x1280
    for c in (512, 256, 256, 128):
        h = b.conv(x, c // 2, k=1, act="relu6")
        h = b.dwconv(h, k=3, s=2, act="relu6")
        x = b.conv(h, c, k=1, act="relu6")
        feats.append(x)
    _ssd_heads(b, feats, anchors=[3, 6, 6, 6, 6, 6], lite=True)
    return b.build(), b


# --------------------------------------------------------------------------
# EfficientDet-Lite0
# --------------------------------------------------------------------------


def _bifpn_fuse(b: GraphBuilder, xs: List[str], act: str = "relu6") -> str:
    y = xs[0]
    for x in xs[1:]:
        y = b.add(y, x)
    y = b.dwconv(y, k=3, act=act)
    return b.conv(y, b.g.tensors[y].hwc[2], k=1, act="none")


def efficientdet_lite0(res: int = 320) -> Tuple[Graph, GraphBuilder]:
    b = GraphBuilder("efficientdet_lite0")
    x = b.input((res, res, 3))
    x = b.conv(x, 32, k=3, s=2, act="relu6")
    cfg = [(1, 3, 16, 1, 1), (6, 3, 24, 2, 2), (6, 5, 40, 2, 2),
           (6, 3, 80, 3, 2), (6, 5, 112, 3, 1), (6, 5, 192, 4, 2),
           (6, 3, 320, 1, 1)]
    taps = {}
    for bi, (t, k, c, n, s) in enumerate(cfg):
        for i in range(n):
            in_c = b.g.tensors[x].hwc[2]
            x = _inv_res(b, x, exp=in_c * t, out_c=c,
                         s=s if i == 0 else 1, k=k)
        taps[bi] = x
    W = 64                                            # BiFPN width (lite0)
    p3 = b.conv(taps[2], W, k=1)                      # 40x40
    p4 = b.conv(taps[4], W, k=1)                      # 20x20
    p5 = b.conv(taps[6], W, k=1)                      # 10x10
    p6 = b.maxpool(b.conv(taps[6], W, k=1), k=3, s=2, pad="same")  # 5x5
    p7 = b.maxpool(p6, k=3, s=2, pad="same")          # 3x3
    levels = [p3, p4, p5, p6, p7]
    for _ in range(3):                                # BiFPN repeats
        # top-down
        td = [levels[-1]]
        for i in range(len(levels) - 2, -1, -1):
            up = b.resize(td[-1], 2)
            h, w, _ = b.g.tensors[levels[i]].hwc
            uh, uw, _ = b.g.tensors[up].hwc
            if (uh, uw) != (h, w):                    # odd-size crop via pool
                up = b.maxpool(up, k=(uh - h + 1), s=1, pad="valid")
            td.append(_bifpn_fuse(b, [levels[i], up]))
        td = td[::-1]
        # bottom-up
        out = [td[0]]
        for i in range(1, len(levels)):
            down = b.maxpool(out[-1], k=3, s=2, pad="same")
            ins = [td[i], down] + ([levels[i]] if i < len(levels) - 1 else [])
            out.append(_bifpn_fuse(b, ins))
        levels = out
    # class / box nets: 3 dw-sep convs + head, shared structure per level
    n_anchor, n_cls = 9, 90
    for lv in levels:
        h = lv
        for _ in range(3):
            h = b.dwconv(h, k=3, act="relu6")
            h = b.conv(h, W, k=1, act="none")
        b.mark_output(b.conv(b.dwconv(h, k=3), n_anchor * n_cls, k=1))
        h2 = lv
        for _ in range(3):
            h2 = b.dwconv(h2, k=3, act="relu6")
            h2 = b.conv(h2, W, k=1, act="none")
        b.mark_output(b.conv(b.dwconv(h2, k=3), n_anchor * 4, k=1))
    return b.build(), b


# --------------------------------------------------------------------------
# YOLOv8
# --------------------------------------------------------------------------


def _yolov8(name: str, width: float, depth: float, res: int,
            seg: bool = False) -> Tuple[Graph, GraphBuilder]:
    b = GraphBuilder(name)

    def W(c):
        return max(8, int(round(c * width / 8)) * 8)

    def D(n):
        return max(1, round(n * depth))

    x = b.input((res, res, 3))
    x = _cbs(b, x, W(64), k=3, s=2)                   # P1
    x = _cbs(b, x, W(128), k=3, s=2)                  # P2
    x = _c2f(b, x, W(128), D(3))
    x = _cbs(b, x, W(256), k=3, s=2)                  # P3
    p3 = _c2f(b, x, W(256), D(6))
    x = _cbs(b, p3, W(512), k=3, s=2)                 # P4
    p4 = _c2f(b, x, W(512), D(6))
    x = _cbs(b, p4, W(1024), k=3, s=2)                # P5
    x = _c2f(b, x, W(1024), D(3))
    p5 = _sppf(b, x, W(1024))
    # PAN-FPN neck
    u = b.resize(p5, 2)
    n4 = _c2f(b, b.concat([u, p4]), W(512), D(3), shortcut=False)
    u = b.resize(n4, 2)
    n3 = _c2f(b, b.concat([u, p3]), W(256), D(3), shortcut=False)   # out P3
    d = _cbs(b, n3, W(256), k=3, s=2)
    n4o = _c2f(b, b.concat([d, n4]), W(512), D(3), shortcut=False)  # out P4
    d = _cbs(b, n4o, W(512), k=3, s=2)
    n5o = _c2f(b, b.concat([d, p5]), W(1024), D(3), shortcut=False)  # out P5
    outs = [n3, n4o, n5o]
    # detect head
    nc, reg = 80, 16
    c2 = max(16, W(256) // 4, reg * 4)
    c3 = max(W(256), min(nc, 100))
    for f in outs:
        h = _cbs(b, f, c2, k=3)
        h = _cbs(b, h, c2, k=3)
        b.mark_output(b.conv(h, 4 * reg, k=1))
        h = _cbs(b, f, c3, k=3)
        h = _cbs(b, h, c3, k=3)
        b.mark_output(b.conv(h, nc, k=1))
    if seg:
        nm = 32
        c4 = max(W(256) // 4, nm)
        for f in outs:                                # mask coefficients
            h = _cbs(b, f, c4, k=3)
            h = _cbs(b, h, c4, k=3)
            b.mark_output(b.conv(h, nm, k=1))
        # proto net on P3
        cp = max(W(256), nm * 2)
        h = _cbs(b, n3, cp, k=3)
        h = b.resize(h, 2)
        h = _cbs(b, h, cp, k=3)
        b.mark_output(_cbs(b, h, nm, k=1))
    return b.build(), b


def yolov8n_det(res: int = 640) -> Tuple[Graph, GraphBuilder]:
    return _yolov8("yolov8n_det", width=0.25, depth=1 / 3, res=res)


def yolov8n_seg(res: int = 640) -> Tuple[Graph, GraphBuilder]:
    return _yolov8("yolov8n_seg", width=0.25, depth=1 / 3, res=res,
                   seg=True)


def yolov8s_det(res: int = 640) -> Tuple[Graph, GraphBuilder]:
    return _yolov8("yolov8s_det", width=0.50, depth=1 / 3, res=res)


# --------------------------------------------------------------------------
# DAMO-YOLO-NL class model (CSP backbone + GFPN-style neck, ZeroHead)
# --------------------------------------------------------------------------


def damo_yolo_nl(res: int = 640) -> Tuple[Graph, GraphBuilder]:
    """DAMO-YOLO Nano-Large class: TinyNAS-style light CSP backbone with a
    parameter-heavy (but low-resolution) RepGFPN neck and ZeroHead — the
    published Nl operating point is 3.05 GMACs / 5.69 M params @640."""
    b = GraphBuilder("damo_yolo_nl")
    x = b.input((res, res, 3))
    x = _cbs(b, x, 12, k=3, s=2)
    x = _cbs(b, x, 24, k=3, s=2)
    x = _c2f(b, x, 24, 1)
    x = _cbs(b, x, 48, k=3, s=2)
    p3 = _c2f(b, x, 48, 2)                            # 80x80x48
    x = _cbs(b, p3, 96, k=3, s=2)
    p4 = _c2f(b, x, 96, 2)                            # 40x40x96
    x = _cbs(b, p4, 192, k=3, s=2)
    x = _c2f(b, x, 192, 1)
    p5 = _sppf(b, x, 192)                             # 20x20x192
    # RepGFPN-style neck: params concentrated at low-res fused scales
    u = b.resize(p5, 2)
    m4 = _c2f(b, b.concat([u, p4]), 128, 1, shortcut=False)
    u = b.resize(m4, 2)
    m3 = _c2f(b, b.concat([u, p3]), 64, 1, shortcut=False)   # 80x80x64
    d = _cbs(b, m3, 128, k=3, s=2)
    m4o = _c2f(b, b.concat([d, m4, p4]), 160, 1, shortcut=False)
    d = _cbs(b, m4o, 256, k=3, s=2)
    m5o = _c2f(b, b.concat([d, p5]), 512, 2, shortcut=False)  # 20x20x512
    # ZeroHead: 1x1 projection + predictors per scale
    nc, reg = 80, 16
    for f, c in [(m3, 64), (m4o, 128), (m5o, 256)]:
        h = _cbs(b, f, c, k=1)
        b.mark_output(b.conv(h, 4 * reg, k=1))
        b.mark_output(b.conv(h, nc, k=1))
    return b.build(), b


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

#: name -> (builder, native resolution, Table-IV GMACs, Table-IV Mparams)
VISION_MODELS: Dict[str, Tuple[Callable[..., Tuple[Graph, GraphBuilder]],
                               int, float, float]] = {
    "mobilenet_v1": (mobilenet_v1, 224, 0.57, 4.2),
    "mobilenet_v2": (mobilenet_v2, 224, 0.30, 3.4),
    "mobilenet_v3_min": (mobilenet_v3_min, 224, 0.21, 3.9),
    "resnet50_v1": (resnet50_v1, 224, 2.0, 25.6),
    "efficientnet_lite0": (efficientnet_lite0, 224, 0.41, 4.7),
    "efficientdet_lite0": (efficientdet_lite0, 320, 1.27, 3.9),
    "yolov8n_det": (yolov8n_det, 640, 4.35, 3.2),
    "yolov8s_det": (yolov8s_det, 640, 14.3, 11.2),
    "yolov8n_seg": (yolov8n_seg, 640, 6.3, 3.4),
    "mobilenet_v1_ssd": (mobilenet_v1_ssd, 300, 1.3, 5.1),
    "mobilenet_v2_ssd": (mobilenet_v2_ssd, 300, 0.8, 4.3),
    "damo_yolo_nl": (damo_yolo_nl, 640, 3.0, 5.7),
}


#: (name, resolution) -> pristine (graph, builder) template.  Templates
#: are never handed out (callers mutate graphs: PTQ dtype/qparams
#: annotation, mark_output) — build() returns structural clones sharing
#: only the read-only weight arrays.
_BUILD_CACHE: Dict[Tuple[str, int], Tuple[Graph, GraphBuilder]] = {}


def _clone_graph(g: Graph) -> Graph:
    ng = Graph(g.name)
    for t in g.tensors.values():
        ng.tensors[t.name] = Tensor(t.name, t.shape, t.kind, t.dtype,
                                    t.producer, list(t.consumers),
                                    t.scale, t.qparams)
    for op in g.ops:
        nop = Op(op.name, op.kind, list(op.inputs), list(op.outputs),
                 dict(op.attrs))
        ng.ops.append(nop)
        ng._op_index[nop.name] = nop
    return ng


def _clone_built(tpl: Tuple[Graph, GraphBuilder]
                 ) -> Tuple[Graph, GraphBuilder]:
    g, b = tpl
    ng = _clone_graph(g)
    nb = GraphBuilder.__new__(GraphBuilder)
    nb.g = ng
    nb._ctr = b._ctr
    # replicate the template rng's advanced state so building further
    # ops on a clone draws the same weights the memo=False path would
    nb._rng = np.random.default_rng(0)
    nb._rng.bit_generator.state = b._rng.bit_generator.state
    nb._weights = dict(b._weights)    # arrays shared, treated read-only
    return ng, nb


def build_cache_clear() -> None:
    _BUILD_CACHE.clear()


def build(name: str, res_scale: float = 1.0, memo: bool = True
          ) -> Tuple[Graph, GraphBuilder]:
    fn, res, _, _ = VISION_MODELS[name]
    r = int(res * res_scale)
    r = max(32, (r // 32) * 32)                       # keep strides clean
    if not memo:
        return fn(r)
    key = (name, r)
    tpl = _BUILD_CACHE.get(key)
    if tpl is None:
        tpl = _BUILD_CACHE[key] = fn(r)
    return _clone_built(tpl)


def build_quantized(name: str, res_scale: float = 1.0, samples: int = 4,
                    method: str = "minmax", percentile: float = 99.9,
                    weight_dtype: str = "int8", seed: int = 0):
    """Build + calibrate + PTQ-quantize one benchmark model.

    Calibration uses `samples` synthetic normal inputs (the graphs carry
    deterministic pseudo-random weights, so synthetic activations
    exercise the same dynamic range a real input pipeline would here).
    Returns ``(graph, builder, QuantizedModel)`` — the graph is the
    quantized (annotated) one."""
    from repro import quant

    g, b = build(name, res_scale=res_scale)
    cal = quant.synthetic_calibration(g, samples=samples, seed=seed)
    calib = quant.calibrate(g, b._weights, cal, method=method,
                            percentile=percentile)
    qm = quant.quantize_graph(g, b._weights, calib,
                              weight_dtype=weight_dtype)
    quant.measure_quant_error(qm, cal)   # basis of the calibrated tol
    return g, b, qm


def table4_targets(name: str) -> Tuple[float, float]:
    _, _, gmacs, mparams = VISION_MODELS[name]
    return gmacs, mparams
