"""LM decoder frontend — prefill and single-token decode Graphs.

The causal-operator subsystem's model builder: a tiny transformer
decoder block stack (pre-norm attention + MLP, the whisper-tiny /
GPT-2 layer shape) emitted as a :class:`repro.core.ir.Graph` on the
NPU compile path.  Activations are laid out ``(S, 1, d_model)`` — the
sequence maps onto the H/row axis, so the compiler's row tiling *is*
token tiling and every existing scheduling/allocation pass applies
unchanged.

One graph definition covers both serving phases:

* **prefill** — ``seq = P`` prompt tokens, ``pos = 0``: every layer
  projects Q/K/V for all P rows, appends K/V at cache rows ``[0, P)``
  and runs causally-masked attention over them;
* **decode**  — ``seq = 1``, ``pos = t``: one new token appends at
  cache row ``t`` and attends to rows ``[0, t]``.

KV caches thread through the *static* graph as inputs **and** outputs:
each layer's ``kvappend`` takes the previous cache state plus the new
rows and produces the updated cache, which is marked as a model output
so :class:`repro.api.DecodeSession` can feed it back as the next
step's input.  Cache capacity (``kv_len``) is a compile-time bucket —
``bucket_for`` picks the smallest configured bucket that fits, so all
requests at similar sequence positions share one compiled program (the
bucket enters the graph fingerprint through the cache shapes and each
attention op's ``kv_len`` attr, which keys the pipeline cache).

Weight sharing across variants: :class:`~repro.core.ir.GraphBuilder`
names parameters by op-creation order and draws their values from a
seeded RNG keyed only by parameter *shape* order — the op sequence of
a decoder stack is independent of ``seq``/``kv_len``, so the prefill
graph, every decode bucket, and every grown bucket all carry
identically-named, identically-valued weights.  One calibration /
quantization result transfers across buckets (asserted in
``tests/test_lm_compile.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.core.ir import Graph, GraphBuilder, reference_execute

#: KV-cache capacity buckets (tokens).  A request is served at the
#: smallest bucket that fits its current sequence position; crossing a
#: bucket boundary re-targets the next-larger bucket's compiled program
#: (cache contents copy forward, weights are shared by construction).
SEQ_BUCKETS = (8, 16, 32, 64, 128)


@dataclass(frozen=True)
class LMSpec:
    """Decoder-stack dimensions (a scaled-down whisper-tiny decoder)."""

    name: str = "lm-tiny"
    n_layers: int = 2
    d_model: int = 48
    n_heads: int = 6
    d_ff: int = 192
    vocab: int = 96
    max_seq: int = 128
    act: str = "gelu"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def tiny_spec(scale: int = 8, n_layers: int = 2, vocab: int = 96,
              max_seq: int = 128) -> LMSpec:
    """Whisper-tiny decoder dims divided by ``scale`` (heads kept, so
    head_dim shrinks): the compile/serve path exercises the real layer
    topology at test-friendly cost."""
    c = WHISPER_TINY
    return LMSpec(name=f"lm-tiny-x{scale}", n_layers=n_layers,
                  d_model=c.d_model // scale, n_heads=c.n_heads,
                  d_ff=c.d_ff // scale, vocab=vocab, max_seq=max_seq,
                  act=c.act)


def bucket_for(n: int, buckets: Tuple[int, ...] = SEQ_BUCKETS) -> int:
    """Smallest configured bucket >= n (clamps to the largest)."""
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


# --------------------------------------------------------------------------
# Graph builder
# --------------------------------------------------------------------------


def build_decoder(spec: LMSpec, seq: int, kv_len: int, seed: int = 0
                  ) -> Tuple[Graph, GraphBuilder]:
    """A ``seq``-token decoder step against ``kv_len``-capacity caches.

    Inputs: ``x`` (seq, 1, d_model) token embeddings, ``pos`` (1,1,1)
    tokens already in the cache, and per layer ``k_cache{L}`` /
    ``v_cache{L}`` (kv_len, 1, d_model).  Outputs: ``logits``
    (seq, 1, vocab) plus every layer's updated cache."""
    if not 1 <= seq <= kv_len:
        raise ValueError(f"seq {seq} must be in [1, kv_len {kv_len}]")
    d = spec.d_model
    b = GraphBuilder(f"{spec.name}-L{spec.n_layers}-s{seq}-kv{kv_len}",
                     seed=seed)
    x = b.input((seq, 1, d), name="x")
    pos = b.input((1, 1, 1), name="pos")
    cache_in: List[Tuple[str, str]] = []
    for L in range(spec.n_layers):
        cache_in.append((b.input((kv_len, 1, d), name=f"k_cache{L}"),
                         b.input((kv_len, 1, d), name=f"v_cache{L}")))

    h = x
    for L in range(spec.n_layers):
        k_in, v_in = cache_in[L]
        hn = b.layernorm(h)
        q = b.matmul(hn, d)
        kk = b.matmul(hn, d)
        vv = b.matmul(hn, d)
        k_new = b.kvappend(k_in, kk, pos)
        v_new = b.kvappend(v_in, vv, pos)
        att = b.attention(q, k_new, v_new, pos, heads=spec.n_heads)
        h = b.add(h, b.matmul(att, d))
        hn2 = b.layernorm(h)
        f1 = b.matmul(hn2, spec.d_ff, act=spec.act)
        h = b.add(h, b.matmul(f1, d))
        b.mark_output(k_new)
        b.mark_output(v_new)

    hf = b.layernorm(h)
    logits = b.matmul(hf, spec.vocab)
    b.mark_output(logits)
    g = b.build()
    return g, b


def cache_io(g: Graph) -> Dict[str, str]:
    """cache-input name -> cache-output name, from the graph itself
    (each ``kvappend`` rewrites exactly one cache)."""
    return {op.inputs[0]: op.outputs[0]
            for op in g.ops if op.kind == "kvappend"}


def logits_name(g: Graph) -> str:
    """The logits output (the only non-cache output)."""
    caches = set(cache_io(g).values())
    names = [t.name for t in g.outputs if t.name not in caches]
    assert len(names) == 1, names
    return names[0]


# --------------------------------------------------------------------------
# Embeddings + calibration
# --------------------------------------------------------------------------


def embedding_table(spec: LMSpec, seed: int = 0) -> np.ndarray:
    """Deterministic (vocab, d_model) token-embedding table, same
    small-int value family as the builder's weights (int8-friendly)."""
    rng = np.random.default_rng(seed + 7919)
    return (rng.integers(-4, 5, size=(spec.vocab, spec.d_model))
            .astype(np.float32) / 16.0)


def embed(table: np.ndarray, ids) -> np.ndarray:
    """Token ids -> (len(ids), 1, d_model) embedding rows."""
    ids = np.asarray(ids, dtype=np.int64).reshape(-1)
    return table[ids][:, None, :].astype(np.float32)


def lm_calibration(g: Graph, weights: Dict[str, np.ndarray],
                   spec: LMSpec, samples: int = 6, seed: int = 0
                   ) -> List[Dict[str, np.ndarray]]:
    """Calibration feeds that walk a real decode: sample 0 starts from
    empty caches at pos 0, every later sample feeds the previous
    sample's *appended* caches back in with the position advanced.  The
    range observers therefore see actual K/V projection values (not
    synthetic noise) and every position of the bucket, which is what
    makes the tied cache qparams and the attention masks calibrated for
    the whole serving range."""
    rng = np.random.default_rng(seed)
    table = embedding_table(spec, seed)
    seq = g.tensors["x"].shape[0]
    io = cache_io(g)
    kv = g.tensors[next(iter(io))].shape[0]
    cache_feed = {name: np.zeros(g.tensors[name].shape, np.float32)
                  for name in io}
    pos = 0
    feeds: List[Dict[str, np.ndarray]] = []
    for _ in range(max(1, samples)):
        ids = rng.integers(0, spec.vocab, size=seq)
        feed = dict(cache_feed)
        feed["x"] = embed(table, ids)
        feed["pos"] = np.full((1, 1, 1), float(pos), np.float32)
        feeds.append(feed)
        vals = reference_execute(g, feed, weights)
        cache_feed = {ci: vals[co] for ci, co in io.items()}
        pos = min(pos + seq, max(kv - seq, 0))
    return feeds


# --------------------------------------------------------------------------
# Compile helper (PTQ-aware)
# --------------------------------------------------------------------------


def compile_decoder(spec: LMSpec, seq: int, kv_len: int,
                    precision: str = "float32", config=None,
                    options=None, seed: int = 0,
                    calib_samples: int = 6, cache: bool = True):
    """Build + compile one decoder variant into a
    :class:`repro.api.CompiledModel`.  ``precision="int8"`` runs the
    PTQ flow over :func:`lm_calibration` feeds (decode-realistic cache
    states), not the generic synthetic set."""
    import repro.api as api
    from repro import quant

    g, b = build_decoder(spec, seq, kv_len, seed=seed)
    if precision == "int8":
        weights = dict(b._weights)
        feeds = lm_calibration(g, weights, spec, samples=calib_samples,
                               seed=seed)
        table = quant.calibrate(g, weights, feeds)
        qm = quant.quantize_graph(g, weights, table)
        quant.measure_quant_error(qm, feeds)
        return api.compile(qm, config, options, cache=cache,
                           name=g.name, calibration=table)
    return api.compile((g, b), config, options, precision=precision,
                       cache=cache, name=g.name)
